//! One scheme's full training run on the virtual MEC clock
//! (paper §III-E "Coded Federated Aggregation" and §V-A "Schemes").
//!
//! Per round, every participating node's gradient is *really* executed
//! through the PJRT grad artifact; the delay model only decides arrivals
//! and the simulated wall-clock cost of the round:
//!
//! * **naive uncoded** — wait for all `n` clients; round costs `max_j T_j`.
//! * **greedy uncoded (ψ)** — wait for the fastest `(1−ψ)n`; round costs
//!   the order statistic; stragglers' gradients are *discarded* (this is
//!   what starves classes under non-IID sharding).
//! * **CodedFedL (δ)** — load allocation fixes `(t*, ℓ*_j, u*)` once
//!   before training (§III-C); each round costs exactly `t*`; arrivals
//!   are compensated by the coded gradient from the parity data (eq. 30).

use anyhow::{Context, Result};

use super::setup::FedSetup;
use crate::allocation::{self, NodeSpec};
use crate::coding;
use crate::conf::Scheme;
use crate::metrics::{accuracy, History, Point};
use crate::rng::Rng;
use crate::sim::RoundSampler;
use crate::runtime::Runtime;
use crate::tensor::Mat;

/// Result of one scheme's run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub history: History,
    /// CodedFedL's optimal deadline (None for uncoded schemes).
    pub t_star: Option<f64>,
    /// CodedFedL's redundancy u* (rows of parity processed per round).
    pub u_star: Option<usize>,
    /// One-time parity upload overhead added to the clock (seconds).
    pub parity_overhead: f64,
    /// Final model (q × c).
    pub theta: Mat,
}

/// CodedFedL state prepared before training (per global mini-batch parity).
struct CodedState {
    t_star: f64,
    u_star: usize,
    /// Per-client processed-subset masks (length `local_batch`, reused for
    /// every mini-batch of that client as §III-D fixes the subset).
    masks: Vec<Vec<f32>>,
    /// Per-step composite parity: `steps × (X̌ [u_max, q], Y̌ [u_max, c])`.
    parity: Vec<(Mat, Mat)>,
    /// `1 − P(T_C ≤ t*)` for the coded-gradient scale of eq. (28).
    pnr_server: f64,
    parity_overhead: f64,
}

/// Run `scheme` to completion over `setup`, computing gradients with `rt`.
pub fn run_scheme(setup: &FedSetup, rt: &Runtime, scheme: Scheme) -> Result<TrainOutcome> {
    let cfg = &setup.cfg;
    let m = setup.m() as f32;
    let n = cfg.clients;
    let (q, c) = (cfg.q, cfg.classes);

    // Scheme-specific RNG streams (same seed base => reproducible; split
    // by a scheme tag so coded's generator draws don't perturb naive's
    // delay draws).
    let tag = match scheme {
        Scheme::NaiveUncoded => 101,
        Scheme::GreedyUncoded { .. } => 102,
        Scheme::Coded { .. } => 103,
    };
    let mut root = Rng::seed_from(setup.seed ^ 0x5EED_0000);
    let mut delay_rng = root.split(tag);
    let mut code_rng = root.split(tag + 1000);

    let coded_state = match scheme {
        Scheme::Coded { delta } => Some(prepare_coded(setup, rt, delta, &mut code_rng)?),
        _ => None,
    };

    // Per-round processed loads (drive compute-time sampling).
    let client_loads: Vec<f64> = match &coded_state {
        Some(cs) => cs.masks.iter().map(|m| m.iter().sum::<f32>() as f64).collect(),
        None => vec![cfg.local_batch as f64; n],
    };
    let sampler = RoundSampler::new(
        setup.clients.clone(),
        setup.server,
        client_loads,
        coded_state.as_ref().map_or(0.0, |c| c.u_star as f64),
    );

    let full_mask = vec![1.0f32; cfg.local_batch];
    let mut theta = Mat::zeros(q, c);
    let mut history = History::new(scheme.label());
    let mut clock = coded_state.as_ref().map_or(0.0, |c| c.parity_overhead);

    for iter in 0..cfg.total_iters() {
        let epoch = iter / cfg.steps_per_epoch;
        let step = iter % cfg.steps_per_epoch;
        let lr = setup.effective_lr(epoch) as f32;
        let delays = sampler.sample(&mut delay_rng);
        // θ is reused by every grad call this round (EXPERIMENTS.md §Perf).
        let theta_lit = rt.prepare_theta(&theta)?;

        // --- gradient aggregation under the scheme's waiting policy ---
        let mut agg = Mat::zeros(q, c);
        let round_time;
        let mut returned = 0.0f32; // aggregate return (for greedy scaling)
        match (&scheme, &coded_state) {
            (Scheme::NaiveUncoded, _) => {
                for j in 0..n {
                    let g = client_grad(rt, setup, j, step, &theta_lit, &full_mask)?;
                    agg.axpy(1.0, &g);
                }
                returned = m;
                round_time = delays.max_client_time();
            }
            (Scheme::GreedyUncoded { psi }, _) => {
                let k = (((1.0 - psi) * n as f64).round() as usize).clamp(1, n);
                let (t_k, winners) = delays.kth_fastest(k);
                for &j in &winners {
                    let g = client_grad(rt, setup, j, step, &theta_lit, &full_mask)?;
                    agg.axpy(1.0, &g);
                    returned += cfg.local_batch as f32;
                }
                round_time = t_k;
            }
            (Scheme::Coded { .. }, Some(cs)) => {
                // Uncoded part: clients that make the deadline (eq. 29).
                for (j, arrived) in delays.arrivals(cs.t_star).iter().enumerate() {
                    if *arrived && cs.masks[j].iter().any(|&v| v > 0.0) {
                        let g = client_grad(rt, setup, j, step, &theta_lit, &cs.masks[j])?;
                        agg.axpy(1.0, &g);
                    }
                }
                // Coded part (eq. 28): gradient over this step's parity,
                // scaled by 1/((1−pnr_C)·u*).
                if delays.server_t <= cs.t_star {
                    let (xp, yp) = &cs.parity[step];
                    let ones = vec![1.0f32; xp.rows()];
                    let gc = rt
                        .grad_prepared(xp, yp, &theta_lit, &ones)
                        .context("coded gradient over parity data")?;
                    let scale = 1.0 / ((1.0 - cs.pnr_server) as f32 * cs.u_star as f32);
                    agg.axpy(scale, &gc);
                }
                returned = m;
                round_time = cs.t_star;
            }
            (Scheme::Coded { .. }, None) => unreachable!(),
        }

        // g_M = (1/m̂)·agg + λθ  (eq. 30 + the §V-A L2 regulariser).
        // m̂ = m for naive/coded (stochastically complete return) and the
        // actual aggregate return (1−ψ)m for greedy.
        let denom = if returned > 0.0 { returned } else { m };
        agg.scale(1.0 / denom);
        agg.axpy(cfg.l2 as f32, &theta);

        // θ ← θ − μ_r g_M  (eq. 5).
        theta.axpy(-lr, &agg);

        clock += round_time;

        // --- evaluation ---
        let logits = rt.predict(&setup.test_xhat, &theta)?;
        let acc = accuracy(&logits, &setup.test_labels);
        let loss = eval_train_loss(rt, setup, &theta)?;
        history.push(Point { iter: iter + 1, sim_time: clock, accuracy: acc, train_loss: loss });
    }

    Ok(TrainOutcome {
        history,
        t_star: coded_state.as_ref().map(|c| c.t_star),
        u_star: coded_state.as_ref().map(|c| c.u_star),
        parity_overhead: coded_state.as_ref().map_or(0.0, |c| c.parity_overhead),
        theta,
    })
}

/// One client's unnormalised masked gradient over its `step`-th mini-batch.
fn client_grad(
    rt: &Runtime,
    setup: &FedSetup,
    j: usize,
    step: usize,
    theta: &crate::runtime::PreparedTheta,
    mask: &[f32],
) -> Result<Mat> {
    let cd = &setup.client_data[j];
    rt.grad_prepared(&cd.xhat[step], &cd.y[step], theta, mask)
        .with_context(|| format!("client {j} gradient (step {step})"))
}

/// How many clients the per-iteration loss probe samples. Sampling a
/// fixed prefix (deterministic) keeps the curve comparable across
/// iterations while cutting ~30 % off coordinator overhead at n = 30
/// (EXPERIMENTS.md §Perf iteration 1). The probe is telemetry only — it
/// never feeds back into training.
const LOSS_PROBE_CLIENTS: usize = 4;

/// Training objective `1/(2m_probe) Σ ||X̂θ − Y||² + (λ/2)||θ||²` over the
/// first mini-batch of a fixed client sample (cheap proxy, logged for the
/// loss curve required by the end-to-end driver).
fn eval_train_loss(rt: &Runtime, setup: &FedSetup, theta: &Mat) -> Result<f64> {
    let mut sum = 0.0f64;
    let mut rows = 0usize;
    for cd in setup.client_data.iter().take(LOSS_PROBE_CLIENTS) {
        let logits = rt.predict(&cd.xhat[0], theta)?;
        for r in 0..logits.rows() {
            let lrow = logits.row(r);
            let yrow = cd.y[0].row(r);
            for (p, t) in lrow.iter().zip(yrow) {
                let d = (p - t) as f64;
                sum += d * d;
            }
        }
        rows += logits.rows();
    }
    let l2 = setup.cfg.l2 * (theta.fro_norm() as f64).powi(2);
    Ok(sum / (2.0 * rows as f64) + 0.5 * l2)
}

/// Load allocation (§III-C) + weight matrices (§III-D) + per-step parity
/// datasets (§III-B) for CodedFedL.
fn prepare_coded(
    setup: &FedSetup,
    rt: &Runtime,
    delta: f64,
    rng: &mut Rng,
) -> Result<CodedState> {
    let cfg = &setup.cfg;
    let m = setup.m();
    let u_cap = ((delta * m as f64).round() as usize).min(cfg.u_max);
    anyhow::ensure!(u_cap > 0, "delta {delta} gives zero parity rows");

    // --- two-step load allocation over the per-round mini-batch ---
    let mut nodes: Vec<NodeSpec> = setup
        .clients
        .iter()
        .map(|p| NodeSpec { params: *p, max_load: cfg.local_batch as f64 })
        .collect();
    nodes.push(NodeSpec { params: setup.server, max_load: u_cap as f64 });
    let alloc = allocation::solve(&nodes, m as f64)
        .map_err(|e| anyhow::anyhow!("load allocation failed: {e}"))?;
    let t_star = alloc.t_star;

    // Integer loads; pnr re-evaluated at the rounded load for exactness.
    let ell_star: Vec<usize> = alloc.loads[..cfg.clients]
        .iter()
        .map(|&l| (l.floor() as usize).min(cfg.local_batch))
        .collect();
    let u_star = (alloc.u_star().floor() as usize).clamp(1, u_cap);
    let pnr_server = 1.0 - setup.server.cdf(t_star, u_star as f64);
    anyhow::ensure!(
        pnr_server < 1.0,
        "server never returns by t* — parameters are inconsistent"
    );

    // --- per-client processed subsets + weight vectors (§III-D) ---
    let mut masks = Vec::with_capacity(cfg.clients);
    let mut weights = Vec::with_capacity(cfg.clients);
    for (j, client) in setup.clients.iter().enumerate() {
        let processed = coding::sample_processed(cfg.local_batch, ell_star[j], rng);
        let pnr1 = if ell_star[j] > 0 {
            1.0 - client.cdf(t_star, ell_star[j] as f64)
        } else {
            1.0
        };
        weights.push(coding::weight_vector(&processed, pnr1));
        masks.push(processed.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect());
    }

    // --- distributed encoding per global mini-batch (§V-A) ---
    let mut parity: Vec<(Mat, Mat)> = Vec::with_capacity(cfg.steps_per_epoch);
    for step in 0..cfg.steps_per_epoch {
        let mut xp_acc: Option<Mat> = None;
        let mut yp_acc: Option<Mat> = None;
        for j in 0..cfg.clients {
            let g = coding::generator_matrix(cfg.generator, u_star, cfg.local_batch, rng);
            let cd = &setup.client_data[j];
            let (xp, yp) = rt
                .encode(&g, &weights[j], &cd.xhat[step], &cd.y[step])
                .with_context(|| format!("encoding client {j}, step {step}"))?;
            match (&mut xp_acc, &mut yp_acc) {
                (Some(xa), Some(ya)) => {
                    xa.axpy(1.0, &xp);
                    ya.axpy(1.0, &yp);
                }
                _ => {
                    xp_acc = Some(xp);
                    yp_acc = Some(yp);
                }
            }
        }
        // Trim parity to the live u* rows (encode pads G to u_max with
        // zero rows, whose parity is exactly zero).
        let xp = xp_acc.unwrap().rows_slice(0, u_star);
        let yp = yp_acc.unwrap().rows_slice(0, u_star);
        parity.push((xp, yp));
    }

    // One-time parity upload overhead (Fig. 4(a) inset): clients upload in
    // parallel; the clock pays the slowest client's total upload across
    // all steps_per_epoch parity sets.
    let parity_overhead = setup
        .clients
        .iter()
        .map(|cl| {
            setup.fleet_spec.parity_upload_secs(cl, u_star) * cfg.steps_per_epoch as f64
        })
        .fold(0.0, f64::max);

    Ok(CodedState {
        t_star,
        u_star,
        masks,
        parity,
        pnr_server,
        parity_overhead,
    })
}
