//! The scheme-agnostic per-round training engine (paper §III-E).
//!
//! [`run`] owns everything every scheme shares: the virtual MEC clock,
//! per-round delay sampling, gradient execution (native or PJRT) against
//! the round's prepared θ, the learning-rate schedule, the model update of
//! eq. (5), periodic evaluation (`eval_every`), [`crate::metrics::History`]
//! recording and the [`RoundObserver`] event stream. Waiting/aggregation
//! policy lives entirely behind the [`Scheme`] trait (`rust/src/schemes/`).
//!
//! Delay sampling is scenario-aware: each round the engine resets a
//! [`FleetView`] to the setup's base per-leg links, lets the configured
//! [`Scenario`] (`[scenario]` / `--scenario`) modulate it — dropouts,
//! fading, compute bursts — and samples the per-leg event timeline into a
//! reusable [`RoundTrace`]. Schemes receive the trace through
//! [`RoundCtx`] and its totals through the usual
//! [`crate::sim::RoundDelays`] view. The default `static` scenario
//! reproduces fixed-fleet histories bit-for-bit (`tests/scenario_determinism.rs`).
//!
//! ## Fleet scale-out (`[fleet] n` / `participation` / `aggregation`)
//!
//! The engine also runs mega-fleets of N = 10^5–10^6 simulated clients:
//! `[fleet] n` sizes the fleet (per-client links come lazily from a
//! sharded [`FleetShards`] store — no monolithic length-N rebuild, ever)
//! and `[fleet] participation = "sample:k=K"` draws a seeded,
//! scheme-independent K-of-N roster per round, so per-round cost scales
//! with K, not N. Rosters are sorted global indices; the round's
//! [`FleetView`], delays and gradient requests all index *slots*
//! `0..K`, and slot state tiles back to the `clients` training shards via
//! [`RoundCtx::data_shard`]. `sample:k=N` realises the identity roster
//! and reproduces `full` bit-for-bit; the defaults skip the roster path
//! entirely. `[fleet] aggregation = "hier:shard=S"` folds the round's
//! gradients through per-shard partial sums on the worker pool (see
//! `fold_hier` below for the pinned, thread-invariant order).
//!
//! ## Faults + deadlines: the degradation ladder
//!
//! Two orthogonal robustness knobs compose with every scheme and every
//! scenario:
//!
//! * `[faults]` / `--faults` ([`crate::sim::fault`]) injects seeded
//!   client crashes, uplink losses (optionally retried with modelled
//!   backoff) and server-side parity loss into the sampled round trace.
//!   Fault draws come from their own RNG stream
//!   ([`crate::sim::fault::FAULT_STREAM_TAG`], split off *after* every
//!   historical stream), so `faults = "none"` histories are bit-for-bit
//!   the pre-fault ones.
//! * `[training] deadline` closes the round at a wall-clock cut — a
//!   fixed `t` or the per-round `q`-quantile of surviving arrivals —
//!   before the scheme plans: clients past the cut are simply gone,
//!   exactly like scenario dropouts.
//!
//! When either knob is active the engine resolves each round's aggregate
//! through an explicit **degradation ladder**, recording which rung fired
//! in [`RoundEvent::outcome`] / [`TrainOutcome::outcomes`]:
//!
//! 1. **Full** — every planned participant folded (rung 0).
//! 2. **Exact decode** — erasure recovery reconstructed the missing
//!    gradients bit-exactly ([`RoundOutcome::ExactDecode`]).
//! 3. **Parity compensation** — the coded parity gradient compensated
//!    the stragglers in expectation ([`RoundOutcome::ParityCompensation`]).
//! 4. **Partial fold** — the arrived subset, renormalised by the data
//!    that actually returned ([`RoundOutcome::PartialFold`]).
//! 5. **Skip** — nothing returned at all: θ is left untouched (no 0/0,
//!    no NaN), the clock still advances by what the server waited, and
//!    the round is reported as [`RoundOutcome::Skip`].
//!
//! Rungs 1–4 are the schemes' own aggregation outcomes; the engine only
//! adds the final skip rung and the bookkeeping. With both knobs off the
//! ladder never engages and the update math below is byte-for-byte the
//! historical path (`deadline = "none"`, `faults = "none"` histories are
//! golden-hash pinned by `tests/scenario_determinism.rs`).
//!
//! ## Communication model (`[comm] codec` / `payload`)
//!
//! Every leg's delay is priced by the modelled payload it carries
//! ([`crate::comm::PayloadModel`], applied to the fleet at setup), and
//! the engine accounts the resulting bytes-on-wire per round:
//! `bytes_down = planned · |θ|` and `bytes_up = uploads · |∇|`, both in
//! modelled bytes after the configured codec, surfaced on
//! [`RoundEvent`] and totalled on [`TrainOutcome`]. When a lossy codec
//! is configured (`q8`, `bitpack`), each arrived gradient is also
//! transcoded — quantize → (bit)pack → dequantize, in place, through
//! the runtime's detected ISA — before the fold, so the aggregate is
//! computed from exactly the f32 matrix the server could reconstruct
//! from the wire payload. `codec = "none"` skips both the transcode
//! loop and the repricing entirely: its histories are bit-for-bit the
//! fixed-payload ones (`tests/payload_determinism.rs`).
//!
//! Per round, every participating node's gradient is *really* executed
//! through the runtime's grad executor — the round's independent client
//! requests go through [`Runtime::grad_batch_into`], which fans them out
//! across the native backend's persistent worker pool and through its
//! construction-time GEMM ISA (`[runtime] simd`); the delay model only
//! decides arrivals and the simulated wall-clock cost of the round.
//! Aggregation always folds the results in plan order, so the aggregate's
//! bits are independent of the thread count — and, for a fixed ISA, of
//! nothing else: `simd = "scalar"` reproduces pre-SIMD histories exactly,
//! while a SIMD ISA yields its own deterministic history (≤ 1e-4 kernel
//! deltas from scalar).
//!
//! ## Steady-state allocation discipline
//!
//! Everything the compute path touches is allocated once, before round 1,
//! and reused for the rest of training: the aggregate, the packed θ panel,
//! the per-request gradient slots, the fleet view, the round trace
//! (legs, totals, sorted events) and the evaluation logits. A warm round
//! therefore performs **zero** heap allocations on the native compute
//! path under every built-in scenario (`tests/alloc_gate.rs` pins this
//! with a counting allocator). The remaining per-round allocations are
//! control-path only — the scheme's `RoundPlan` and the borrowed
//! `GradJob` list, a handful of pointer-sized entries per round.

use std::path::Path;

use anyhow::{Context, Result};

use super::checkpoint::{self, ResumeSpec, Snapshot};
use super::setup::FedSetup;
use crate::comm::{self, PayloadModel};
use crate::metrics::{accuracy, History, OutcomeCounts, Point, RoundOutcome};
use crate::rng::Rng;
use crate::runtime::{GradJob, PreparedTheta, Runtime};
use crate::schemes::{GradRequest, RoundCtx, RoundExec, Scheme};
use crate::sim::fault::{DeadlineSpec, FAULT_STREAM_TAG, SERVER_FAULT_STREAM_TAG};
use crate::sim::scenario::{Scenario, SCENARIO_STREAM_TAG};
use crate::sim::timeline::RoundTrace;
use crate::sim::KthScratch;
use crate::tensor::Mat;
use crate::topology::{
    AggregationMode, FleetShards, FleetView, ParticipationSampler, PARTICIPATION_STREAM_TAG,
};

/// XOR'd into the experiment seed to pin the ladder-tiled mega-fleet's
/// per-client parameter draws ([`crate::topology::FleetSpec::node_at`]) —
/// a stream of its own, off every historical RNG split.
const FLEET_LADDER_SEED: u64 = 0xF1EE_75CA_1E00_0001;

/// Result of one scheme's run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub history: History,
    /// CodedFedL's optimal deadline (None for uncoded schemes).
    pub t_star: Option<f64>,
    /// CodedFedL's redundancy u* (rows of parity processed per round).
    pub u_star: Option<usize>,
    /// One-time parity upload overhead added to the clock (seconds).
    pub parity_overhead: f64,
    /// Degradation-ladder rung histogram over *every* round (evaluated or
    /// not) — how the run actually resolved its aggregates under faults
    /// and deadlines. All-`full` on an unfaulted, deadline-free run.
    pub outcomes: OutcomeCounts,
    /// Non-finite client updates excluded from folds over the whole run
    /// (`faults = corrupt:rate=…`, or natural numeric blow-ups).
    pub corrupted_total: u64,
    /// `Some(round)` when the run restored from a checkpoint and began at
    /// this 0-based round instead of 0 (`[checkpoint] resume`).
    pub resumed_from: Option<usize>,
    /// Total modelled downlink bytes over the run (θ broadcasts to every
    /// planned participant, priced by the `[comm]` payload model).
    pub bytes_down_total: u64,
    /// Total modelled uplink bytes over the run (every uploaded gradient
    /// — arrived or corrupt-excluded — priced after the codec).
    pub bytes_up_total: u64,
    /// Final model (q × c).
    pub theta: Mat,
}

/// One *evaluated* training round, as seen by observers.
///
/// With the default `eval_every = 1` every round is evaluated and
/// observers see one event per round; with `eval_every = k > 1` the
/// engine skips the full-test-set probe on intermediate rounds and
/// observers only see the sampled ones (the final round is always
/// evaluated). [`Point::iter`] / [`RoundEvent::iter`] carry the global
/// iteration either way.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundEvent {
    /// 1-based global iteration (matches [`Point::iter`]).
    pub iter: usize,
    /// 0-based epoch.
    pub epoch: usize,
    /// Mini-batch index within the epoch.
    pub step: usize,
    /// Cumulative simulated MEC clock after this round (seconds).
    pub clock: f64,
    /// Client gradients that arrived and entered the aggregate
    /// (*achieved* participation).
    pub arrivals: usize,
    /// The round's participant slots (*planned* participation — the full
    /// fleet, or the sampled roster size). `arrivals as f64 / planned as
    /// f64` is the round's achieved-participation fraction.
    pub planned: usize,
    /// Which degradation-ladder rung resolved the round's aggregate
    /// (always [`RoundOutcome::Full`] when faults and deadlines are off).
    pub outcome: RoundOutcome,
    /// Arrived gradients excluded from this round's fold because they
    /// were non-finite (`faults = corrupt:rate=…`). Already subtracted
    /// from [`RoundEvent::arrivals`].
    pub corrupted: usize,
    /// Training objective after the round's update.
    pub loss: f64,
    /// Test accuracy after the round's update.
    pub acc: f64,
    /// Modelled downlink bytes this round (θ to every planned slot).
    pub bytes_down: u64,
    /// Modelled uplink bytes this round (every uploaded gradient, priced
    /// after the configured codec).
    pub bytes_up: u64,
}

/// Receives one [`RoundEvent`] per *evaluated* training round (every
/// round at the default `eval_every = 1`). The CLI's progress printer,
/// CSV streamers and test probes all hang off this — nothing needs to
/// reach into engine internals.
pub trait RoundObserver {
    fn on_round(&mut self, event: &RoundEvent);
}

/// A buffering observer: records every event (handy in tests and for
/// post-hoc export).
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    pub events: Vec<RoundEvent>,
}

impl RoundObserver for EventLog {
    fn on_round(&mut self, event: &RoundEvent) {
        self.events.push(*event);
    }
}

/// Run `scheme` to completion over `setup`, computing gradients with `rt`
/// and reporting each round to `observers`.
pub fn run(
    setup: &FedSetup,
    rt: &Runtime,
    scheme: &mut dyn Scheme,
    observers: &mut [&mut dyn RoundObserver],
) -> Result<TrainOutcome> {
    let cfg = &setup.cfg;
    let n = cfg.clients;
    let m = setup.m() as f32;
    let (q, c) = (cfg.q, cfg.classes);

    // Scheme-specific RNG streams (same seed base ⇒ reproducible; split by
    // the scheme's tag so e.g. coded's generator draws don't perturb
    // naive's delay draws). The split order — delays first, then the
    // scheme's private code stream, then the scenario stream — is part of
    // the reproducibility contract with pre-trait runs. The scenario
    // stream's tag is deliberately scheme-independent: every scheme on a
    // session faces the same network realisation (dropout patterns,
    // bursts), which keeps cross-scheme comparisons fair; the `static`
    // scenario never draws from it, preserving pre-scenario histories
    // bit-for-bit.
    let tag = scheme.rng_tag();
    let mut root = Rng::seed_from(setup.seed ^ 0x5EED_0000);
    let mut delay_rng = root.split(tag);
    let mut code_rng = root.split(tag.wrapping_add(1000));
    let mut scenario_rng = root.split(SCENARIO_STREAM_TAG);
    // The participation stream is appended *after* every historical split
    // (`split` advances the root identically for any tag), so the delay,
    // code and scenario sequences above are exactly their
    // pre-participation bits. Like the scenario stream, the tag is
    // scheme-independent: every scheme on a session faces the identical
    // roster realisation. Rosters themselves are drawn from the
    // counter-based `Rng::indexed(part_base, round)` streams, so round
    // r's roster is a pure O(k) function of (seed, r) — independent of
    // fleet size, shard layout and every other stream.
    let mut part_stream = root.split(PARTICIPATION_STREAM_TAG);
    let part_base = part_stream.next_u64();
    // The fault stream is appended after the participation stream — again
    // off every historical split, and again scheme-independent: each
    // scheme on a session faces the identical fault realisation. An
    // inactive plan (`faults = "none"`) never draws from it.
    let mut fault_rng = root.split(FAULT_STREAM_TAG);
    let fault_plan = cfg.faults.build();
    // The server-fault (coordinator-kill) stream is counter-based like
    // participation: appended after every other split, only its base is
    // consumed, and `Rng::indexed(server_base, round)` decides round r's
    // kill in O(1) — which is what lets a *restarted* coordinator
    // re-derive the exact kill schedule without replaying anything.
    let mut server_stream = root.split(SERVER_FAULT_STREAM_TAG);
    let server_base = server_stream.next_u64();
    let server_rate = fault_plan.server_rate();
    let corrupt_rate = fault_plan.corrupt_rate();
    let mut scenario: Box<dyn Scenario> = cfg.scenario.build();
    // Degraded mode (the ladder's skip rung, see the module docs) only
    // engages when a robustness knob is actually on — otherwise the
    // update below is byte-for-byte the historical math.
    let degraded = fault_plan.is_active() || cfg.deadline != DeadlineSpec::None;

    let prep = scheme
        .prepare(setup, rt, &mut code_rng)
        .with_context(|| format!("preparing scheme {}", scheme.label()))?;
    anyhow::ensure!(
        prep.client_loads.len() == n,
        "scheme {} returned {} client loads for {n} clients",
        scheme.label(),
        prep.client_loads.len()
    );

    let client_loads = prep.client_loads;
    let server_load = prep.server_load;

    // --- fleet scale-out state (`[fleet] n` / `participation`) ---
    // With the defaults (no mega-fleet, full participation) `roster_mode`
    // is false and the round loop below runs the historical full-fleet
    // path untouched. Otherwise the engine materialises each round's view
    // over the sampled roster only: the sharded store hands out per-client
    // links lazily (a million-node fleet never builds a monolithic Vec),
    // and per-client prepare-time state tiles across the mega-fleet by
    // data shard (`g % clients`).
    let fleet_size = cfg.fleet_size();
    let roster_mode = cfg.roster_mode();
    cfg.participation
        .validate(fleet_size)
        .map_err(|e| anyhow::anyhow!("[fleet] participation: {e}"))?;
    let mut shards = if fleet_size == n {
        FleetShards::from_links(&setup.client_links)
    } else {
        let mut mega = setup.fleet_spec;
        mega.n = fleet_size;
        FleetShards::ladder(mega, setup.seed ^ FLEET_LADDER_SEED, cfg.shard_size)
    };
    let mut sampler = ParticipationSampler::new(cfg.participation, fleet_size, part_base);
    let mut roster_loads: Vec<f64> = Vec::new();

    let mut theta = Mat::zeros(q, c);
    let mut history = History::new(scheme.label());
    let mut clock = prep.clock_offset;

    // --- round-persistent buffers (steady-state rounds reuse, never
    //     allocate — see the module docs). The fleet view and round trace
    //     are part of the same discipline: the view is reset from the
    //     setup's base links (no clone of the fleet per round beyond the
    //     in-place copy), the scenario modulates it in place, and the
    //     trace samples into held buffers. ---
    let mut agg = Mat::zeros(q, c);
    let mut theta_panel: Vec<f32> = Vec::new();
    let mut grad_outs: Vec<Mat> = Vec::new();
    let mut partials: Vec<Mat> = Vec::new();
    let mut view = FleetView::from_base(&setup.client_links, setup.server);
    let mut trace = RoundTrace::with_capacity(n);
    let mut eval_logits = Mat::zeros(setup.test_xhat.rows(), c);
    let mut probe_logits = Mat::zeros(cfg.local_batch, c);
    // Quantile-deadline selection scratch — same reuse discipline, so a
    // warm deadline round stays on the 0-alloc gate.
    let mut kth_scratch = KthScratch::default();
    // Codec transcode scratch (`[comm] codec`): the per-row code and
    // nibble buffers are sized once here, so warm quantized rounds stay
    // on the 0-alloc gate too. codec = "none" never touches them.
    let codec = cfg.codec;
    let codec_isa = rt.isa().unwrap_or(crate::tensor::Isa::Scalar);
    let mut codec_scratch = comm::CodecScratch::default();
    if !codec.is_none() {
        codec_scratch.reserve(c);
    }
    // Bytes-on-wire pricing: one model for the whole run, matching the
    // scales `FedSetup::build` applied to the fleet's legs.
    let payload_model =
        PayloadModel::new(q, c, codec, cfg.payload, setup.fleet_spec.overhead);
    let theta_down_b = payload_model.theta_down_bytes();
    let grad_up_b = payload_model.grad_up_bytes();
    let mut bytes_down_total: u64 = 0;
    let mut bytes_up_total: u64 = 0;
    let mut outcomes = OutcomeCounts::default();
    // A scenario that never perturbs the fleet (`static`) lets full-fleet
    // rounds skip the O(n) view reset entirely — the view built above is
    // already this round's fleet, bit-for-bit.
    let scenario_resets = scenario.perturbs_fleet();

    // --- checkpoint/resume seam ---
    // All cross-round scheme state (CodedFedL's parity datasets, code
    // coefficients, t*/u*) is a deterministic function of `prepare`'s
    // code-stream draws, so resume re-runs `prepare` (done above) and
    // then rewinds the four sequential streams to their checkpointed
    // positions; the counter-based participation and server-kill streams
    // need only their bases, re-derived identically from the seed.
    let scheme_label = scheme.label();
    let fingerprint = checkpoint::fingerprint(cfg);
    let ckpt_every = cfg.checkpoint_every;
    let checkpointing = ckpt_every > 0;
    let ckpt_path_buf = cfg
        .checkpoint_path
        .clone()
        .unwrap_or_else(|| checkpoint::default_path(&cfg.artifacts_dir, tag));
    let ckpt_path = Path::new(&ckpt_path_buf);
    let mut corrupted_total: u64 = 0;
    let mut corrupt_flags: Vec<bool> = Vec::new();
    let mut start_iter: usize = 0;
    let mut resumed_from: Option<usize> = None;
    let resume_snap: Option<Snapshot> = match &cfg.resume {
        ResumeSpec::Off => None,
        ResumeSpec::Auto if !ckpt_path.exists() => None,
        ResumeSpec::Auto => Some(
            checkpoint::load(ckpt_path)
                .map_err(|e| anyhow::anyhow!("[checkpoint] resume = \"auto\": {e}"))?,
        ),
        ResumeSpec::Path(p) => Some(
            checkpoint::load(Path::new(p))
                .map_err(|e| anyhow::anyhow!("[checkpoint] resume: {e}"))?,
        ),
    };
    if let Some(snap) = &resume_snap {
        snap.verify(fingerprint, &scheme_label, q, c)
            .map_err(|e| anyhow::anyhow!("[checkpoint] resume: {e}"))?;
        restore_state(
            snap,
            &mut theta,
            &mut clock,
            &mut history,
            &mut outcomes,
            &mut corrupted_total,
            &mut bytes_down_total,
            &mut bytes_up_total,
            &mut delay_rng,
            &mut code_rng,
            &mut scenario_rng,
            &mut fault_rng,
        );
        start_iter = snap.next_iter as usize;
        resumed_from = Some(start_iter);
    }
    // In-process kill-and-restart (`faults = server:rate=…`) restores
    // from the latest snapshot *bytes* — the durable checkpoint when one
    // was written, else the run's initial state (a full restart).
    let mut restore_bytes: Option<Vec<u8>> = if server_rate > 0.0 {
        let snap = capture_state(
            fingerprint,
            &scheme_label,
            start_iter,
            clock,
            &theta,
            &delay_rng,
            &code_rng,
            &scenario_rng,
            &fault_rng,
            &outcomes,
            corrupted_total,
            bytes_down_total,
            bytes_up_total,
            &history,
        );
        Some(snap.encode())
    } else {
        None
    };
    // Strictly-increasing high-water mark of rounds that already killed
    // the coordinator: replayed pre-kill rounds must not re-fire (each
    // round kills at most once per run, so recovery always terminates).
    let mut kill_hw: Option<usize> = None;

    let total_iters = cfg.total_iters();
    let mut iter = start_iter;
    while iter < total_iters {
        let epoch = iter / cfg.steps_per_epoch;
        let step = iter % cfg.steps_per_epoch;
        let lr = setup.effective_lr(epoch) as f32;
        // Roster (if sampling), then scenario (this round's fleet), then
        // the per-leg timeline draw — on the full fixed fleet the
        // delay-RNG sequence is exactly the one-shot sampler's.
        let roster: Option<&[u32]> = if roster_mode {
            let r = sampler.draw(iter);
            roster_loads.clear();
            roster_loads.extend(r.iter().map(|&g| client_loads[g as usize % n]));
            view.reset_roster(&mut shards, r, setup.server);
            Some(r)
        } else {
            if scenario_resets {
                view.reset_from(&setup.client_links, setup.server);
            }
            None
        };
        scenario.begin_round(iter, &mut view, &mut scenario_rng);
        let loads: &[f64] = if roster_mode { &roster_loads } else { &client_loads };
        trace.sample_into(&view, loads, server_load, &mut delay_rng);
        // Faults mutate the sampled trace in place (crashes, uplink
        // losses, parity loss), then the deadline closes it: clients past
        // the cut are gone before any scheme looks, exactly like scenario
        // dropouts — which is why every scheme composes unmodified.
        fault_plan.apply(&mut trace, &mut fault_rng);
        if corrupt_rate > 0.0 {
            // Scheme-independent draw, one per present client in slot
            // order, into the engine's reused flag buffer; the flagged
            // gradients are poisoned after execution below.
            fault_plan.draw_corrupt(&trace, &mut corrupt_flags, &mut fault_rng);
        }
        // --- in-process coordinator kill (`faults = server:rate=…`) ---
        // The check sits mid-round, after this round's trace, fault and
        // corruption draws already consumed RNG state: a kill genuinely
        // rewinds partially-consumed streams to the snapshot, and the
        // recovery invariant (resumed ≡ uninterrupted, bit-identical)
        // makes the realized history equal `faults = none`'s. Replayed
        // rounds re-emit observer events — consumers that must not see
        // duplicates dedup by `RoundEvent::iter`, keeping the last.
        if server_rate > 0.0
            && kill_hw.map_or(true, |h| iter > h)
            && Rng::indexed(server_base, iter as u64).next_f64() < server_rate
        {
            kill_hw = Some(iter);
            let bytes =
                restore_bytes.as_ref().expect("server faults always hold a restore point");
            let snap = Snapshot::decode(bytes)
                .map_err(|e| anyhow::anyhow!("restarting after server fault: {e}"))?;
            restore_state(
                &snap,
                &mut theta,
                &mut clock,
                &mut history,
                &mut outcomes,
                &mut corrupted_total,
                &mut bytes_down_total,
                &mut bytes_up_total,
                &mut delay_rng,
                &mut code_rng,
                &mut scenario_rng,
                &mut fault_rng,
            );
            iter = snap.next_iter as usize;
            continue;
        }
        let deadline_t = match cfg.deadline {
            DeadlineSpec::None => None,
            DeadlineSpec::Fixed { t } => Some(t),
            DeadlineSpec::Quantile { q } => {
                // The q-quantile of this round's *surviving* arrivals:
                // wait for ⌈q·k⌉ of the k clients faults left reachable.
                let k = trace.delays().present_count();
                if k == 0 {
                    None
                } else {
                    let kth = ((q * k as f64).ceil() as usize).clamp(1, k);
                    let (t, _) = trace
                        .delays()
                        .kth_fastest_into(kth, &mut kth_scratch)
                        .map_err(anyhow::Error::msg)?;
                    Some(t)
                }
            }
        };
        if let Some(t) = deadline_t {
            trace.close_at(t);
        }
        let ctx = RoundCtx { iter, epoch, step, setup, trace: &trace, roster };

        // --- the scheme's waiting policy decides who participates ---
        agg.as_mut_slice().fill(0.0);
        let (arrivals, planned, cost, corrupted_now, excluded_rows) = {
            // θ is packed once and borrowed by every grad call this round
            // (rust/PERF.md §Design); the scope bounds the borrow so the
            // update below can mutate θ again.
            let theta_prep = rt.prepare_theta_into(&theta, &mut theta_panel)?;
            let plan = scheme.plan_round(&ctx, trace.delays())?;
            let participants = ctx.participants();
            for req in &plan.requests {
                anyhow::ensure!(
                    req.client < participants,
                    "scheme {} requested client {} of {participants}",
                    scheme.label(),
                    req.client
                );
            }
            // The round's independent client gradients run as one batch
            // (parallel across the persistent worker pool) into the
            // engine's reusable output slots… Each participant slot trains
            // on its data shard (`ctx.data_shard` — the identity on the
            // full fixed fleet, `roster[slot] % clients` under sampling).
            let jobs: Vec<GradJob> = plan
                .requests
                .iter()
                .map(|req| {
                    let cd = &setup.client_data[ctx.data_shard(req.client)];
                    GradJob { xhat: &cd.xhat[step], y: &cd.y[step], mask: &req.mask }
                })
                .collect();
            while grad_outs.len() < jobs.len() {
                grad_outs.push(Mat::zeros(q, c));
            }
            rt.grad_batch_into(&jobs, &theta_prep, &mut grad_outs[..jobs.len()])
                .with_context(|| {
                    format!("executing {} client gradients (step {step})", jobs.len())
                })?;
            // Corrupt faults poison the flagged clients' just-computed
            // gradients with non-finite garbage; the fold below must
            // never see a non-finite update, so every request's gradient
            // is screened and offenders are zero-filled in place (a zero
            // contribution drops out of both flat and hier folds) and
            // counted. The screen only runs under `corrupt:` — the
            // fault-free hot loop is untouched.
            let mut corrupted_now = 0usize;
            let mut excluded_rows = 0.0f32;
            if corrupt_rate > 0.0 {
                for (req, g) in plan.requests.iter().zip(grad_outs.iter_mut()) {
                    if corrupt_flags.get(req.client).copied().unwrap_or(false) {
                        g.as_mut_slice().fill(f32::NAN);
                    }
                    if !g.as_slice().iter().all(|v| v.is_finite()) {
                        g.as_mut_slice().fill(0.0);
                        corrupted_now += 1;
                        excluded_rows += req.mask.iter().sum::<f32>();
                    }
                }
            }
            // Lossy uplink codec (`[comm] codec`): every uploaded gradient
            // is transcoded in place — quantize → (bit)pack → dequantize
            // through the runtime's detected ISA — so the fold below sees
            // exactly the f32 matrix the server could reconstruct from the
            // modelled wire payload. Runs after the corrupt screen (zeroed
            // offenders quantize to an exact all-zero row) and before any
            // aggregation; `codec = "none"` skips the loop entirely, so
            // unquantized histories keep their bits.
            if !codec.is_none() {
                for g in grad_outs[..jobs.len()].iter_mut() {
                    comm::transcode_mat(codec_isa, codec, g, &mut codec_scratch);
                }
            }
            // …and fold in a pinned order, fixing the aggregate's bits
            // independently of the thread count: flat mode folds
            // sequentially in plan order (the historical fold), hier mode
            // folds plan-order groups into per-shard partial sums (each
            // written by exactly one pool thread) before the root fold.
            match cfg.aggregation {
                AggregationMode::Flat => {
                    for (req, g) in plan.requests.iter().zip(&grad_outs) {
                        agg.axpy(req.scale, g);
                    }
                }
                AggregationMode::Hier { shard } => fold_hier(
                    &mut agg,
                    &plan.requests,
                    &grad_outs[..jobs.len()],
                    shard,
                    &mut partials,
                    rt,
                ),
            }
            // The exec handle also exposes the per-request gradients just
            // computed (plan order) — exact-recovery aggregation encodes
            // and decodes over them without re-running anything.
            let exec = RoundExec::new(rt, &theta_prep, &grad_outs[..jobs.len()]);
            let cost = scheme.aggregate(&ctx, trace.delays(), &plan, &exec, &mut agg)?;
            (
                plan.requests.len() - corrupted_now,
                participants,
                cost,
                corrupted_now,
                excluded_rows,
            )
        };
        corrupted_total += corrupted_now as u64;
        // Bytes-on-wire this round: θ went down to every planned slot;
        // every executed request uploaded a gradient (the corrupt screen
        // excludes updates from the fold, not from the wire).
        let bytes_down = (planned as f64 * theta_down_b).round() as u64;
        let bytes_up = ((arrivals + corrupted_now) as f64 * grad_up_b).round() as u64;
        bytes_down_total += bytes_down;
        bytes_up_total += bytes_up;

        // --- degradation-ladder resolution (module docs) ---
        // The scheme reported how *its* aggregation resolved (rungs 1–4);
        // the engine downgrades to the skip rung when degraded mode is on
        // and the round folded nothing at all: no planned requests and no
        // server-side contribution (parity compensation and exact decode
        // both write into `agg` even with zero arrived clients).
        let outcome = if degraded
            && arrivals == 0
            && !matches!(
                cost.outcome,
                RoundOutcome::ParityCompensation | RoundOutcome::ExactDecode
            ) {
            RoundOutcome::Skip
        } else if corrupted_now > 0 && cost.outcome == RoundOutcome::Full {
            // Some planned gradients were excluded as non-finite: the
            // fold was partial even though every planned client arrived.
            RoundOutcome::PartialFold
        } else {
            cost.outcome
        };
        outcomes.record(outcome);

        if outcome == RoundOutcome::Skip {
            // Skip rung: θ is left untouched — no 0/0, no NaN, just a
            // documented stall — but the server still waited, so the
            // clock advances by the scheme's round time when it charged
            // one, else the deadline it held open, else the last event
            // that actually completed (the surviving downlinks).
            let dt = if cost.sim_seconds.is_finite() && cost.sim_seconds > 0.0 {
                cost.sim_seconds
            } else if let Some(t) = deadline_t {
                t
            } else {
                // Events sort ascending with ∞ last; charge the last
                // *finite* completion (0 on a fully silent round).
                trace
                    .events()
                    .iter()
                    .rev()
                    .map(|ev| ev.time())
                    .find(|t| t.is_finite())
                    .unwrap_or(0.0)
            };
            clock += dt;
        } else {
            // g_M = (1/m̂)·agg + λθ  (eq. 30 + the §V-A L2 regulariser).
            // m̂ = m for stochastically complete schemes (returned = 0)
            // and the actual aggregate return (e.g. greedy's (1−ψ)m)
            // otherwise. With faults and deadlines off this branch is
            // unconditional and byte-for-byte the historical update.
            // m̂ additionally sheds the rows of excluded (corrupted)
            // gradients when the scheme counted actual returns;
            // stochastically complete schemes (returned = 0) keep m —
            // an excluded update is a zero gradient there, not fewer
            // samples.
            let denom = if cost.returned > 0.0 {
                if corrupted_now > 0 {
                    (cost.returned - excluded_rows).max(1.0)
                } else {
                    cost.returned
                }
            } else {
                m
            };
            agg.scale(1.0 / denom);
            agg.axpy(cfg.l2 as f32, &theta);

            // θ ← θ − μ_r g_M  (eq. 5).
            theta.axpy(-lr, &agg);

            clock += cost.sim_seconds;
        }

        // --- evaluation + event fan-out (sampled every `eval_every`
        //     rounds; the final round is always evaluated) ---
        let evaluate = (iter + 1) % cfg.eval_every == 0 || iter + 1 == total_iters;
        if evaluate {
            let theta_prep = rt.prepare_theta_into(&theta, &mut theta_panel)?;
            rt.predict_into(&setup.test_xhat, &theta_prep, &mut eval_logits)?;
            let acc = accuracy(&eval_logits, &setup.test_labels);
            let loss = eval_train_loss(rt, setup, &theta_prep, &theta, &mut probe_logits)?;
            history.push(Point {
                iter: iter + 1,
                sim_time: clock,
                accuracy: acc,
                train_loss: loss,
            });
            let event = RoundEvent {
                iter: iter + 1,
                epoch,
                step,
                clock,
                arrivals,
                planned,
                outcome,
                corrupted: corrupted_now,
                loss,
                acc,
                bytes_down,
                bytes_up,
            };
            for obs in observers.iter_mut() {
                obs.on_round(&event);
            }
        }

        // --- periodic checkpoint (`[checkpoint] every = R`) ---
        // Warm non-checkpoint rounds pay only this modulo test (0-alloc,
        // gated by tests/alloc_gate.rs); checkpoint rounds snapshot,
        // encode and atomically persist, and the encoded bytes double as
        // the in-process restore point for `server:` kills.
        if checkpointing && (iter + 1) % ckpt_every == 0 {
            let snap = capture_state(
                fingerprint,
                &scheme_label,
                iter + 1,
                clock,
                &theta,
                &delay_rng,
                &code_rng,
                &scenario_rng,
                &fault_rng,
                &outcomes,
                corrupted_total,
                bytes_down_total,
                bytes_up_total,
                &history,
            );
            let bytes = snap.encode();
            crate::io::atomic_write(ckpt_path, &bytes).with_context(|| {
                format!("writing checkpoint {} (round {})", ckpt_path.display(), iter + 1)
            })?;
            if restore_bytes.is_some() {
                restore_bytes = Some(bytes);
            }
        }
        iter += 1;
    }

    // Graceful shutdown: leave a final checkpoint so a follow-up run with
    // a longer schedule (resume = "auto") continues where this one ended.
    if checkpointing {
        let snap = capture_state(
            fingerprint,
            &scheme_label,
            total_iters,
            clock,
            &theta,
            &delay_rng,
            &code_rng,
            &scenario_rng,
            &fault_rng,
            &outcomes,
            corrupted_total,
            bytes_down_total,
            bytes_up_total,
            &history,
        );
        checkpoint::write(ckpt_path, &snap)
            .map_err(|e| anyhow::anyhow!("writing final checkpoint: {e}"))?;
    }

    let stats = scheme.stats();
    Ok(TrainOutcome {
        history,
        t_star: stats.t_star,
        u_star: stats.u_star,
        parity_overhead: stats.parity_overhead,
        outcomes,
        corrupted_total,
        resumed_from,
        bytes_down_total,
        bytes_up_total,
        theta,
    })
}

/// Snapshot the engine's full resumable state at a round boundary
/// (`next_iter` = the first round the restored run will execute).
#[allow(clippy::too_many_arguments)]
fn capture_state(
    fingerprint: u64,
    scheme_label: &str,
    next_iter: usize,
    clock: f64,
    theta: &Mat,
    delay_rng: &Rng,
    code_rng: &Rng,
    scenario_rng: &Rng,
    fault_rng: &Rng,
    outcomes: &OutcomeCounts,
    corrupted_total: u64,
    bytes_down_total: u64,
    bytes_up_total: u64,
    history: &History,
) -> Snapshot {
    Snapshot {
        config_fingerprint: fingerprint,
        scheme_label: scheme_label.to_string(),
        next_iter: next_iter as u64,
        clock,
        theta_rows: theta.rows() as u32,
        theta_cols: theta.cols() as u32,
        theta: theta.as_slice().to_vec(),
        delay_rng: delay_rng.state(),
        code_rng: code_rng.state(),
        scenario_rng: scenario_rng.state(),
        fault_rng: fault_rng.state(),
        outcomes: outcomes.as_array(),
        corrupted_total,
        bytes_down_total,
        bytes_up_total,
        history: history.points.clone(),
    }
}

/// Rewind the engine to a snapshot: θ, clock, history, outcome counts and
/// all four sequential RNG stream positions. The inverse of
/// [`capture_state`]; shape/config agreement was verified beforehand.
#[allow(clippy::too_many_arguments)]
fn restore_state(
    snap: &Snapshot,
    theta: &mut Mat,
    clock: &mut f64,
    history: &mut History,
    outcomes: &mut OutcomeCounts,
    corrupted_total: &mut u64,
    bytes_down_total: &mut u64,
    bytes_up_total: &mut u64,
    delay_rng: &mut Rng,
    code_rng: &mut Rng,
    scenario_rng: &mut Rng,
    fault_rng: &mut Rng,
) {
    theta.as_mut_slice().copy_from_slice(&snap.theta);
    *clock = snap.clock;
    history.points.clear();
    history.points.extend_from_slice(&snap.history);
    let [full, exact_decode, parity, partial, skip] = snap.outcomes;
    *outcomes = OutcomeCounts { full, exact_decode, parity, partial, skip };
    *corrupted_total = snap.corrupted_total;
    *bytes_down_total = snap.bytes_down_total;
    *bytes_up_total = snap.bytes_up_total;
    *delay_rng = Rng::from_state(snap.delay_rng);
    *code_rng = Rng::from_state(snap.code_rng);
    *scenario_rng = Rng::from_state(snap.scenario_rng);
    *fault_rng = Rng::from_state(snap.fault_rng);
}

/// Raw pointer to the hierarchical fold's partial-sum slots. Shared with
/// the pool workers, which write *disjoint* group-index ranges (see
/// [`fold_hier`]) — the disjointness is what makes the access sound.
struct MatSlots(*mut Mat);

unsafe impl Sync for MatSlots {}

/// Hierarchical aggregation (`[fleet] aggregation = "hier:shard=S"`):
/// fold the round's planned gradients through per-shard partial sums
/// before the root fold.
///
/// The fold order is pinned and documented: partial `i` sums requests
/// `i·S .. (i+1)·S` *sequentially in plan order*, and the root then folds
/// the partials sequentially in group order. Each partial is written by
/// exactly one thread, and neither level's order depends on how groups
/// are partitioned across the pool — so the aggregate's bits depend only
/// on the plan and `S`, never on the thread count
/// (`tests/participation_determinism.rs` pins this against a hand-rolled
/// reference). Group sums run concurrently across the native worker pool
/// when one exists (serial fallback on PJRT); `partials` persists across
/// rounds, so warm rounds allocate nothing here.
fn fold_hier(
    agg: &mut Mat,
    requests: &[GradRequest],
    grads: &[Mat],
    shard: usize,
    partials: &mut Vec<Mat>,
    rt: &Runtime,
) {
    let shard = shard.max(1);
    let groups = requests.len().div_ceil(shard);
    while partials.len() < groups {
        partials.push(Mat::zeros(agg.rows(), agg.cols()));
    }
    let fold_group = |gi: usize, out: &mut Mat| {
        out.as_mut_slice().fill(0.0);
        let lo = gi * shard;
        let hi = (lo + shard).min(requests.len());
        for (req, g) in requests[lo..hi].iter().zip(&grads[lo..hi]) {
            out.axpy(req.scale, g);
        }
    };
    let parts = rt.worker_pool().map_or(1, |p| p.threads()).min(groups);
    if parts > 1 {
        let pool = rt.worker_pool().expect("parts > 1 implies a native pool");
        let live = &mut partials[..groups];
        let slots = MatSlots(live.as_mut_ptr());
        pool.run(parts, &|part, _scratch| {
            // Contiguous ceil-split of the group range per part; parts own
            // disjoint ranges, so each partial has exactly one writer.
            let per = groups.div_ceil(parts);
            let lo = (part * per).min(groups);
            let hi = (lo + per).min(groups);
            for gi in lo..hi {
                let out = unsafe { &mut *slots.0.add(gi) };
                fold_group(gi, out);
            }
        });
    } else {
        for (gi, out) in partials[..groups].iter_mut().enumerate() {
            fold_group(gi, out);
        }
    }
    for out in partials[..groups].iter() {
        agg.axpy(1.0, out);
    }
}

/// How many clients the per-iteration loss probe samples. Sampling a
/// fixed prefix (deterministic) keeps the curve comparable across
/// iterations while cutting ~30 % off coordinator overhead at n = 30
/// (EXPERIMENTS.md §Perf iteration 1). The probe is telemetry only — it
/// never feeds back into training.
const LOSS_PROBE_CLIENTS: usize = 4;

/// Training objective `1/(2m_probe) Σ ||X̂θ − Y||² + (λ/2)||θ||²` over the
/// first mini-batch of a fixed client sample (cheap proxy, logged for the
/// loss curve required by the end-to-end driver). `logits` is the
/// engine's reusable probe buffer (`[local_batch, c]`).
fn eval_train_loss(
    rt: &Runtime,
    setup: &FedSetup,
    prepared: &PreparedTheta,
    theta: &Mat,
    logits: &mut Mat,
) -> Result<f64> {
    let mut sum = 0.0f64;
    let mut rows = 0usize;
    for cd in setup.client_data.iter().take(LOSS_PROBE_CLIENTS) {
        rt.predict_into(&cd.xhat[0], prepared, logits)?;
        for r in 0..logits.rows() {
            let lrow = logits.row(r);
            let yrow = cd.y[0].row(r);
            for (p, t) in lrow.iter().zip(yrow) {
                let d = (p - t) as f64;
                sum += d * d;
            }
        }
        rows += logits.rows();
    }
    let l2 = setup.cfg.l2 * (theta.fro_norm() as f64).powi(2);
    Ok(sum / (2.0 * rows as f64) + 0.5 * l2)
}
