//! The scheme-agnostic per-round training engine (paper §III-E).
//!
//! [`run`] owns everything every scheme shares: the virtual MEC clock,
//! per-round delay sampling, gradient execution (native or PJRT) against
//! the round's prepared θ, the learning-rate schedule, the model update of
//! eq. (5), periodic evaluation (`eval_every`), [`crate::metrics::History`]
//! recording and the [`RoundObserver`] event stream. Waiting/aggregation
//! policy lives entirely behind the [`Scheme`] trait (`rust/src/schemes/`).
//!
//! Delay sampling is scenario-aware: each round the engine resets a
//! [`FleetView`] to the setup's base per-leg links, lets the configured
//! [`Scenario`] (`[scenario]` / `--scenario`) modulate it — dropouts,
//! fading, compute bursts — and samples the per-leg event timeline into a
//! reusable [`RoundTrace`]. Schemes receive the trace through
//! [`RoundCtx`] and its totals through the usual
//! [`crate::sim::RoundDelays`] view. The default `static` scenario
//! reproduces fixed-fleet histories bit-for-bit (`tests/scenario_determinism.rs`).
//!
//! Per round, every participating node's gradient is *really* executed
//! through the runtime's grad executor — the round's independent client
//! requests go through [`Runtime::grad_batch_into`], which fans them out
//! across the native backend's persistent worker pool and through its
//! construction-time GEMM ISA (`[runtime] simd`); the delay model only
//! decides arrivals and the simulated wall-clock cost of the round.
//! Aggregation always folds the results in plan order, so the aggregate's
//! bits are independent of the thread count — and, for a fixed ISA, of
//! nothing else: `simd = "scalar"` reproduces pre-SIMD histories exactly,
//! while a SIMD ISA yields its own deterministic history (≤ 1e-4 kernel
//! deltas from scalar).
//!
//! ## Steady-state allocation discipline
//!
//! Everything the compute path touches is allocated once, before round 1,
//! and reused for the rest of training: the aggregate, the packed θ panel,
//! the per-request gradient slots, the fleet view, the round trace
//! (legs, totals, sorted events) and the evaluation logits. A warm round
//! therefore performs **zero** heap allocations on the native compute
//! path under every built-in scenario (`tests/alloc_gate.rs` pins this
//! with a counting allocator). The remaining per-round allocations are
//! control-path only — the scheme's `RoundPlan` and the borrowed
//! `GradJob` list, a handful of pointer-sized entries per round.

use anyhow::{Context, Result};

use super::setup::FedSetup;
use crate::metrics::{accuracy, History, Point};
use crate::rng::Rng;
use crate::runtime::{GradJob, PreparedTheta, Runtime};
use crate::schemes::{RoundCtx, RoundExec, Scheme};
use crate::sim::scenario::{Scenario, SCENARIO_STREAM_TAG};
use crate::sim::timeline::RoundTrace;
use crate::tensor::Mat;
use crate::topology::FleetView;

/// Result of one scheme's run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub history: History,
    /// CodedFedL's optimal deadline (None for uncoded schemes).
    pub t_star: Option<f64>,
    /// CodedFedL's redundancy u* (rows of parity processed per round).
    pub u_star: Option<usize>,
    /// One-time parity upload overhead added to the clock (seconds).
    pub parity_overhead: f64,
    /// Final model (q × c).
    pub theta: Mat,
}

/// One *evaluated* training round, as seen by observers.
///
/// With the default `eval_every = 1` every round is evaluated and
/// observers see one event per round; with `eval_every = k > 1` the
/// engine skips the full-test-set probe on intermediate rounds and
/// observers only see the sampled ones (the final round is always
/// evaluated). [`Point::iter`] / [`RoundEvent::iter`] carry the global
/// iteration either way.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundEvent {
    /// 1-based global iteration (matches [`Point::iter`]).
    pub iter: usize,
    /// 0-based epoch.
    pub epoch: usize,
    /// Mini-batch index within the epoch.
    pub step: usize,
    /// Cumulative simulated MEC clock after this round (seconds).
    pub clock: f64,
    /// Client gradients that arrived and entered the aggregate.
    pub arrivals: usize,
    /// Training objective after the round's update.
    pub loss: f64,
    /// Test accuracy after the round's update.
    pub acc: f64,
}

/// Receives one [`RoundEvent`] per *evaluated* training round (every
/// round at the default `eval_every = 1`). The CLI's progress printer,
/// CSV streamers and test probes all hang off this — nothing needs to
/// reach into engine internals.
pub trait RoundObserver {
    fn on_round(&mut self, event: &RoundEvent);
}

/// A buffering observer: records every event (handy in tests and for
/// post-hoc export).
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    pub events: Vec<RoundEvent>,
}

impl RoundObserver for EventLog {
    fn on_round(&mut self, event: &RoundEvent) {
        self.events.push(*event);
    }
}

/// Run `scheme` to completion over `setup`, computing gradients with `rt`
/// and reporting each round to `observers`.
pub fn run(
    setup: &FedSetup,
    rt: &Runtime,
    scheme: &mut dyn Scheme,
    observers: &mut [&mut dyn RoundObserver],
) -> Result<TrainOutcome> {
    let cfg = &setup.cfg;
    let n = cfg.clients;
    let m = setup.m() as f32;
    let (q, c) = (cfg.q, cfg.classes);

    // Scheme-specific RNG streams (same seed base ⇒ reproducible; split by
    // the scheme's tag so e.g. coded's generator draws don't perturb
    // naive's delay draws). The split order — delays first, then the
    // scheme's private code stream, then the scenario stream — is part of
    // the reproducibility contract with pre-trait runs. The scenario
    // stream's tag is deliberately scheme-independent: every scheme on a
    // session faces the same network realisation (dropout patterns,
    // bursts), which keeps cross-scheme comparisons fair; the `static`
    // scenario never draws from it, preserving pre-scenario histories
    // bit-for-bit.
    let tag = scheme.rng_tag();
    let mut root = Rng::seed_from(setup.seed ^ 0x5EED_0000);
    let mut delay_rng = root.split(tag);
    let mut code_rng = root.split(tag.wrapping_add(1000));
    let mut scenario_rng = root.split(SCENARIO_STREAM_TAG);
    let mut scenario: Box<dyn Scenario> = cfg.scenario.build();

    let prep = scheme
        .prepare(setup, rt, &mut code_rng)
        .with_context(|| format!("preparing scheme {}", scheme.label()))?;
    anyhow::ensure!(
        prep.client_loads.len() == n,
        "scheme {} returned {} client loads for {n} clients",
        scheme.label(),
        prep.client_loads.len()
    );

    let client_loads = prep.client_loads;
    let server_load = prep.server_load;

    let mut theta = Mat::zeros(q, c);
    let mut history = History::new(scheme.label());
    let mut clock = prep.clock_offset;

    // --- round-persistent buffers (steady-state rounds reuse, never
    //     allocate — see the module docs). The fleet view and round trace
    //     are part of the same discipline: the view is reset from the
    //     setup's base links (no clone of the fleet per round beyond the
    //     in-place copy), the scenario modulates it in place, and the
    //     trace samples into held buffers. ---
    let mut agg = Mat::zeros(q, c);
    let mut theta_panel: Vec<f32> = Vec::new();
    let mut grad_outs: Vec<Mat> = Vec::new();
    let mut view = FleetView::from_base(&setup.client_links, setup.server);
    let mut trace = RoundTrace::with_capacity(n);
    let mut eval_logits = Mat::zeros(setup.test_xhat.rows(), c);
    let mut probe_logits = Mat::zeros(cfg.local_batch, c);

    let total_iters = cfg.total_iters();
    for iter in 0..total_iters {
        let epoch = iter / cfg.steps_per_epoch;
        let step = iter % cfg.steps_per_epoch;
        let lr = setup.effective_lr(epoch) as f32;
        // Scenario first (this round's fleet), then the per-leg timeline
        // draw — same delay-RNG sequence as the one-shot sampler.
        view.reset_from(&setup.client_links, setup.server);
        scenario.begin_round(iter, &mut view, &mut scenario_rng);
        trace.sample_into(&view, &client_loads, server_load, &mut delay_rng);
        let ctx = RoundCtx { iter, epoch, step, setup, trace: &trace };

        // --- the scheme's waiting policy decides who participates ---
        agg.as_mut_slice().fill(0.0);
        let (arrivals, cost) = {
            // θ is packed once and borrowed by every grad call this round
            // (rust/PERF.md §Design); the scope bounds the borrow so the
            // update below can mutate θ again.
            let theta_prep = rt.prepare_theta_into(&theta, &mut theta_panel)?;
            let plan = scheme.plan_round(&ctx, trace.delays())?;
            for req in &plan.requests {
                anyhow::ensure!(
                    req.client < n,
                    "scheme {} requested client {} of {n}",
                    scheme.label(),
                    req.client
                );
            }
            // The round's independent client gradients run as one batch
            // (parallel across the persistent worker pool) into the
            // engine's reusable output slots…
            let jobs: Vec<GradJob> = plan
                .requests
                .iter()
                .map(|req| {
                    let cd = &setup.client_data[req.client];
                    GradJob { xhat: &cd.xhat[step], y: &cd.y[step], mask: &req.mask }
                })
                .collect();
            while grad_outs.len() < jobs.len() {
                grad_outs.push(Mat::zeros(q, c));
            }
            rt.grad_batch_into(&jobs, &theta_prep, &mut grad_outs[..jobs.len()])
                .with_context(|| {
                    format!("executing {} client gradients (step {step})", jobs.len())
                })?;
            // …and fold in plan order, fixing the aggregate's bits
            // independently of the thread count.
            for (req, g) in plan.requests.iter().zip(&grad_outs) {
                agg.axpy(req.scale, g);
            }
            // The exec handle also exposes the per-request gradients just
            // computed (plan order) — exact-recovery aggregation encodes
            // and decodes over them without re-running anything.
            let exec = RoundExec::new(rt, &theta_prep, &grad_outs[..jobs.len()]);
            let cost = scheme.aggregate(&ctx, trace.delays(), &plan, &exec, &mut agg)?;
            (plan.requests.len(), cost)
        };

        // g_M = (1/m̂)·agg + λθ  (eq. 30 + the §V-A L2 regulariser).
        // m̂ = m for stochastically complete schemes (returned = 0) and the
        // actual aggregate return (e.g. greedy's (1−ψ)m) otherwise.
        let denom = if cost.returned > 0.0 { cost.returned } else { m };
        agg.scale(1.0 / denom);
        agg.axpy(cfg.l2 as f32, &theta);

        // θ ← θ − μ_r g_M  (eq. 5).
        theta.axpy(-lr, &agg);

        clock += cost.sim_seconds;

        // --- evaluation + event fan-out (sampled every `eval_every`
        //     rounds; the final round is always evaluated) ---
        let evaluate = (iter + 1) % cfg.eval_every == 0 || iter + 1 == total_iters;
        if !evaluate {
            continue;
        }
        let theta_prep = rt.prepare_theta_into(&theta, &mut theta_panel)?;
        rt.predict_into(&setup.test_xhat, &theta_prep, &mut eval_logits)?;
        let acc = accuracy(&eval_logits, &setup.test_labels);
        let loss = eval_train_loss(rt, setup, &theta_prep, &theta, &mut probe_logits)?;
        history.push(Point { iter: iter + 1, sim_time: clock, accuracy: acc, train_loss: loss });
        let event = RoundEvent {
            iter: iter + 1,
            epoch,
            step,
            clock,
            arrivals,
            loss,
            acc,
        };
        for obs in observers.iter_mut() {
            obs.on_round(&event);
        }
    }

    let stats = scheme.stats();
    Ok(TrainOutcome {
        history,
        t_star: stats.t_star,
        u_star: stats.u_star,
        parity_overhead: stats.parity_overhead,
        theta,
    })
}

/// How many clients the per-iteration loss probe samples. Sampling a
/// fixed prefix (deterministic) keeps the curve comparable across
/// iterations while cutting ~30 % off coordinator overhead at n = 30
/// (EXPERIMENTS.md §Perf iteration 1). The probe is telemetry only — it
/// never feeds back into training.
const LOSS_PROBE_CLIENTS: usize = 4;

/// Training objective `1/(2m_probe) Σ ||X̂θ − Y||² + (λ/2)||θ||²` over the
/// first mini-batch of a fixed client sample (cheap proxy, logged for the
/// loss curve required by the end-to-end driver). `logits` is the
/// engine's reusable probe buffer (`[local_batch, c]`).
fn eval_train_loss(
    rt: &Runtime,
    setup: &FedSetup,
    prepared: &PreparedTheta,
    theta: &Mat,
    logits: &mut Mat,
) -> Result<f64> {
    let mut sum = 0.0f64;
    let mut rows = 0usize;
    for cd in setup.client_data.iter().take(LOSS_PROBE_CLIENTS) {
        rt.predict_into(&cd.xhat[0], prepared, logits)?;
        for r in 0..logits.rows() {
            let lrow = logits.row(r);
            let yrow = cd.y[0].row(r);
            for (p, t) in lrow.iter().zip(yrow) {
                let d = (p - t) as f64;
                sum += d * d;
            }
        }
        rows += logits.rows();
    }
    let l2 = setup.cfg.l2 * (theta.fro_norm() as f64).powi(2);
    Ok(sum / (2.0 * rows as f64) + 0.5 * l2)
}
