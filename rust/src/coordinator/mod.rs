//! The CodedFedL coordinator — the paper's system contribution.
//!
//! [`setup::FedSetup`] owns everything shared across schemes for one
//! experiment (fleet, non-IID shards, RFF-embedded data, test set), so
//! naive / greedy / coded runs compare on identical data and delays.
//! [`trainer::run_scheme`] executes one scheme's full training run on the
//! virtual MEC clock, computing every gradient through the PJRT runtime.

pub mod setup;
pub mod trainer;

pub use setup::FedSetup;
pub use trainer::{run_scheme, TrainOutcome};
