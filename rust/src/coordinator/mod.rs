//! The CodedFedL coordinator — the paper's system contribution.
//!
//! [`setup::FedSetup`] owns everything shared across schemes for one
//! experiment (fleet, non-IID shards, RFF-embedded data, test set), so
//! naive / greedy / coded runs compare on identical data and delays.
//! [`engine::run`] executes any [`crate::schemes::Scheme`] to completion
//! on the virtual MEC clock, computing every gradient through the runtime
//! and streaming one [`RoundEvent`] per round to registered
//! [`RoundObserver`]s — resolving each round through the degradation
//! ladder (see the engine module docs) when `[faults]` or a `[training]
//! deadline` is active. [`checkpoint`] makes the loop crash-consistent:
//! periodic CRC-guarded snapshots plus `resume` modes that restart an
//! interrupted run bit-identically. [`trainer::run_scheme`] is the
//! deprecated pre-trait entry point.

pub mod checkpoint;
pub mod engine;
pub mod setup;
pub mod trainer;

pub use crate::metrics::{OutcomeCounts, RoundOutcome};
pub use checkpoint::{CheckpointError, ResumeSpec, Snapshot};
pub use engine::{EventLog, RoundEvent, RoundObserver, TrainOutcome};
pub use setup::FedSetup;
#[allow(deprecated)]
pub use trainer::run_scheme;
