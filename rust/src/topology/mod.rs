//! The paper's wireless MEC fleet construction (§V-A).
//!
//! - LTE downlink/uplink: each client gets 3 resource blocks ⇒ max PHY rate
//!   216 kbps. Effective rates follow the geometric ladder
//!   `{1, k₁, k₁², …, k₁^{n−1}}` (times the max rate) assigned to clients by
//!   a random permutation; erasure probability `p = 0.1` for all links
//!   (constant-failure rate adaptation).
//! - Compute: MAC rates follow the ladder `{1, k₂, …}` with max
//!   3.072·10⁶ MAC/s, `α = 2`; the data-point rate `μ_j` divides the MAC
//!   rate by the MACs per point of the regression gradient (`2·q·c`).
//! - Packets carry one model/gradient (`q·c` scalars, 32 bit, 10%
//!   protocol overhead): `τ_j = b / rate_j`.
//! - The MEC server's computing unit has dedicated, reliable resources
//!   (`P(T_C ≤ t) = 1` in §V-A — we model `p = 0` with server-grade rates).

use crate::delay::asymmetric::AsymNodeParams;
use crate::delay::NodeParams;
use crate::rng::Rng;

/// Fleet-wide asymmetric-link overrides (the `[fleet]` config section;
/// paper footnote 1's non-reciprocal generalisation): per-leg multipliers
/// on the §V-A τ ladder plus per-leg erasure probabilities replacing the
/// reciprocal `p`. `Default` is the reciprocal-equivalent setting (unit
/// multipliers, the paper's `p = 0.1` on both legs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AsymLinkSpec {
    /// Downlink packet-time multiplier applied to each client's ladder τ.
    pub tau_down: f64,
    /// Uplink packet-time multiplier.
    pub tau_up: f64,
    /// Downlink erasure probability (replaces the symmetric `p`).
    pub p_down: f64,
    /// Uplink erasure probability.
    pub p_up: f64,
}

impl Default for AsymLinkSpec {
    fn default() -> Self {
        AsymLinkSpec { tau_down: 1.0, tau_up: 1.0, p_down: 0.1, p_up: 0.1 }
    }
}

impl AsymLinkSpec {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.tau_down > 0.0) || !(self.tau_up > 0.0) {
            return Err(format!(
                "tau_down/tau_up must be > 0 multipliers, got {}/{}",
                self.tau_down, self.tau_up
            ));
        }
        if !(0.0..1.0).contains(&self.p_down) || !(0.0..1.0).contains(&self.p_up) {
            return Err(format!(
                "p_down/p_up must be in [0,1), got {}/{}",
                self.p_down, self.p_up
            ));
        }
        Ok(())
    }
}

/// Knobs of the §V-A fleet; `Default` is the paper's exact setting except
/// for `n`/`q`/`c`, which come from the experiment config.
#[derive(Clone, Copy, Debug)]
pub struct FleetSpec {
    /// Asymmetric downlink/uplink overrides (`None` = the paper's
    /// reciprocal links; see [`AsymLinkSpec`]).
    pub asym: Option<AsymLinkSpec>,
    pub n: usize,
    /// RFF dimension q (packet payload is the q×c model/gradient).
    pub q: usize,
    /// Number of classes c.
    pub c: usize,
    /// Link-rate ladder ratio k₁.
    pub k1: f64,
    /// MAC-rate ladder ratio k₂.
    pub k2: f64,
    /// Max effective PHY information rate in bit/s (3 LTE resource blocks).
    pub max_rate_bps: f64,
    /// Max MAC rate in MAC/s.
    pub max_mac_rate: f64,
    /// Compute/memory ratio α (same for all clients in §V-A).
    pub alpha: f64,
    /// Link erasure probability (same for all clients in §V-A).
    pub p: f64,
    /// Protocol overhead fraction (10%).
    pub overhead: f64,
    /// Bits per scalar (32).
    pub bits_per_scalar: f64,
}

impl FleetSpec {
    pub fn paper(n: usize, q: usize, c: usize) -> Self {
        FleetSpec {
            asym: None,
            n,
            q,
            c,
            k1: 0.95,
            k2: 0.8,
            max_rate_bps: 216_000.0,
            max_mac_rate: 3.072e6,
            alpha: 2.0,
            p: 0.1,
            overhead: 0.1,
            bits_per_scalar: 32.0,
        }
    }

    /// Packet size in bits for one model or gradient transfer (q·c scalars
    /// plus protocol overhead).
    pub fn packet_bits(&self) -> f64 {
        (self.q * self.c) as f64 * self.bits_per_scalar * (1.0 + self.overhead)
    }

    /// MACs needed per data point of the regression gradient
    /// (`X̂θ` then `X̂ᵀR`: 2·q·c multiply–accumulates per row).
    pub fn macs_per_point(&self) -> f64 {
        2.0 * (self.q * self.c) as f64
    }

    /// One-time parity upload time for `u` parity rows of width `q + c`
    /// over client `j`'s uplink (expected retransmissions included) —
    /// the Fig. 4(a) inset overhead.
    pub fn parity_upload_secs(&self, client: &NodeParams, u: usize) -> f64 {
        let bits =
            u as f64 * (self.q + self.c) as f64 * self.bits_per_scalar * (1.0 + self.overhead);
        let packets = bits / self.packet_bits();
        packets * client.tau / (1.0 - client.p)
    }

    /// Build the client fleet. Both ladders are independently permuted
    /// across clients (paper: "assign a random permutation of them").
    pub fn build_clients(&self, rng: &mut Rng) -> Vec<NodeParams> {
        let rate_perm = rng.permutation(self.n);
        let mac_perm = rng.permutation(self.n);
        (0..self.n)
            .map(|j| {
                let rate = self.max_rate_bps * self.k1.powi(rate_perm[j] as i32);
                let macs = self.max_mac_rate * self.k2.powi(mac_perm[j] as i32);
                NodeParams {
                    mu: macs / self.macs_per_point(),
                    alpha: self.alpha,
                    tau: self.packet_bits() / rate,
                    p: self.p,
                }
            })
            .collect()
    }

    /// The MEC server's computing unit: dedicated, reliable, cloud-grade
    /// (§III-C / §V-A). 100× the best client MAC rate, reliable fast link.
    pub fn build_server(&self) -> NodeParams {
        NodeParams {
            mu: 100.0 * self.max_mac_rate / self.macs_per_point(),
            alpha: 100.0,
            tau: self.packet_bits() / (100.0 * self.max_rate_bps),
            p: 0.0,
        }
    }

    /// Per-leg link models for an already-built fleet — the form the
    /// round timeline samples. With `asym = None` every client keeps
    /// reciprocal links (`τ_d = τ_u = τ`, `p_d = p_u = p`), which samples
    /// bit-identically to the base [`NodeParams`] model; with overrides,
    /// the §V-A τ ladder is scaled per leg and the per-leg erasure
    /// probabilities replace the symmetric `p`. Draws no randomness —
    /// the ladder permutation lives entirely in
    /// [`FleetSpec::build_clients`].
    pub fn build_links(&self, clients: &[NodeParams]) -> Vec<AsymNodeParams> {
        clients
            .iter()
            .map(|c| match self.asym {
                None => AsymNodeParams::symmetric(c),
                Some(a) => AsymNodeParams {
                    mu: c.mu,
                    alpha: c.alpha,
                    tau_down: c.tau * a.tau_down,
                    tau_up: c.tau * a.tau_up,
                    p_down: a.p_down,
                    p_up: a.p_up,
                },
            })
            .collect()
    }
}

/// The round's working copy of the fleet — what a
/// [`crate::sim::scenario::Scenario`] modulates before the timeline
/// samples delays. The engine resets it from the base fleet at the top of
/// every round ([`FleetView::reset_from`], allocation-free once warm), so
/// scenarios mutate freely: scale node parameters, mark clients
/// unavailable, slow the server — without touching the experiment's base
/// topology.
#[derive(Clone, Debug)]
pub struct FleetView {
    /// Per-client per-leg node models, this round.
    pub clients: Vec<AsymNodeParams>,
    /// Per-client availability; an unavailable client samples no delay
    /// and carries `T_j = ∞` in the round's delays.
    pub available: Vec<bool>,
    /// The MEC computing unit, this round.
    pub server: NodeParams,
}

impl FleetView {
    /// A view initialised to the base fleet, everyone available.
    pub fn from_base(links: &[AsymNodeParams], server: NodeParams) -> Self {
        let mut view = FleetView {
            clients: Vec::with_capacity(links.len()),
            available: Vec::with_capacity(links.len()),
            server,
        };
        view.reset_from(links, server);
        view
    }

    /// Reset to the base fleet (called at the top of every round). Clears
    /// and refills in place — zero allocations once the buffers reached
    /// fleet size.
    pub fn reset_from(&mut self, links: &[AsymNodeParams], server: NodeParams) {
        self.clients.clear();
        self.clients.extend_from_slice(links);
        self.available.clear();
        self.available.resize(links.len(), true);
        self.server = server;
    }

    /// Number of clients in the fleet.
    pub fn len(&self) -> usize {
        self.clients.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packet_bits_paper_scale() {
        let s = FleetSpec::paper(30, 2000, 10);
        // 2000*10*32*1.1 = 704_000 bits
        assert!((s.packet_bits() - 704_000.0).abs() < 1e-6);
    }

    #[test]
    fn fleet_has_ladder_rates() {
        let s = FleetSpec::paper(30, 2000, 10);
        let clients = s.build_clients(&mut Rng::seed_from(1));
        assert_eq!(clients.len(), 30);
        // fastest link tau = b / 216k; slowest = b / (216k * .95^29)
        let taus: Vec<f64> = clients.iter().map(|c| c.tau).collect();
        let min_tau = taus.iter().cloned().fold(f64::INFINITY, f64::min);
        let max_tau = taus.iter().cloned().fold(0.0, f64::max);
        assert!((min_tau - s.packet_bits() / 216_000.0).abs() < 1e-9);
        let expect_max = s.packet_bits() / (216_000.0 * 0.95f64.powi(29));
        assert!((max_tau - expect_max).abs() < 1e-9);
    }

    #[test]
    fn fleet_mu_ladder_and_params() {
        let s = FleetSpec::paper(30, 2000, 10);
        let clients = s.build_clients(&mut Rng::seed_from(2));
        let mus: Vec<f64> = clients.iter().map(|c| c.mu).collect();
        let max_mu = mus.iter().cloned().fold(0.0, f64::max);
        assert!((max_mu - 3.072e6 / 40_000.0).abs() < 1e-9); // 76.8 pts/s
        for c in &clients {
            assert_eq!(c.alpha, 2.0);
            assert_eq!(c.p, 0.1);
            c.validate().unwrap();
        }
    }

    #[test]
    fn permutation_is_seed_dependent_but_ladder_preserved() {
        let s = FleetSpec::paper(10, 100, 10);
        let a = s.build_clients(&mut Rng::seed_from(3));
        let b = s.build_clients(&mut Rng::seed_from(4));
        let mut ra: Vec<u64> = a.iter().map(|c| c.tau.to_bits()).collect();
        let mut rb: Vec<u64> = b.iter().map(|c| c.tau.to_bits()).collect();
        assert_ne!(ra, rb, "different seeds should permute differently");
        ra.sort_unstable();
        rb.sort_unstable();
        assert_eq!(ra, rb, "the ladder multiset is seed-independent");
    }

    #[test]
    fn server_is_fast_and_reliable() {
        let s = FleetSpec::paper(30, 2000, 10);
        let srv = s.build_server();
        assert_eq!(srv.p, 0.0);
        assert!(srv.mu > 100.0 * 76.0);
        srv.validate().unwrap();
    }

    #[test]
    fn build_links_symmetric_matches_base_and_asym_scales_ladder() {
        let mut spec = FleetSpec::paper(8, 100, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(6));
        // Reciprocal default: per-leg model mirrors the base exactly.
        for (l, c) in spec.build_links(&clients).iter().zip(&clients) {
            assert_eq!(l.tau_down.to_bits(), c.tau.to_bits());
            assert_eq!(l.tau_up.to_bits(), c.tau.to_bits());
            assert_eq!(l.p_down, c.p);
            assert_eq!(l.p_up, c.p);
            assert_eq!(l.mu, c.mu);
            l.validate().unwrap();
        }
        // Asymmetric overrides: the ladder τ is scaled per leg, p replaced.
        spec.asym = Some(AsymLinkSpec { tau_down: 1.0, tau_up: 2.5, p_down: 0.05, p_up: 0.2 });
        for (l, c) in spec.build_links(&clients).iter().zip(&clients) {
            assert!((l.tau_down - c.tau).abs() < 1e-12);
            assert!((l.tau_up - 2.5 * c.tau).abs() < 1e-12);
            assert_eq!((l.p_down, l.p_up), (0.05, 0.2));
            l.validate().unwrap();
        }
    }

    #[test]
    fn asym_link_spec_validates() {
        assert!(AsymLinkSpec::default().validate().is_ok());
        let ok = AsymLinkSpec::default();
        assert!(AsymLinkSpec { tau_down: 0.0, ..ok }.validate().is_err());
        assert!(AsymLinkSpec { tau_up: -1.0, ..ok }.validate().is_err());
        assert!(AsymLinkSpec { p_down: 1.0, ..ok }.validate().is_err());
        assert!(AsymLinkSpec { p_up: -0.1, ..ok }.validate().is_err());
    }

    #[test]
    fn fleet_view_resets_to_base_without_growing() {
        let spec = FleetSpec::paper(5, 100, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(9));
        let links = spec.build_links(&clients);
        let server = spec.build_server();
        let mut view = FleetView::from_base(&links, server);
        assert_eq!(view.len(), 5);
        assert!(!view.is_empty());
        assert!(view.available.iter().all(|&a| a));
        // Scenario-style mutation…
        view.clients[2].mu *= 0.25;
        view.available[4] = false;
        // …is fully undone by the per-round reset.
        view.reset_from(&links, server);
        assert_eq!(view.clients[2].mu, links[2].mu);
        assert!(view.available[4]);
        assert!(view.clients.capacity() >= 5);
    }

    #[test]
    fn parity_upload_scales_with_u() {
        let s = FleetSpec::paper(30, 200, 10);
        let c = NodeParams { mu: 1.0, alpha: 2.0, tau: 2.0, p: 0.1 };
        let t1 = s.parity_upload_secs(&c, 100);
        let t2 = s.parity_upload_secs(&c, 200);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
