//! The paper's wireless MEC fleet construction (§V-A).
//!
//! - LTE downlink/uplink: each client gets 3 resource blocks ⇒ max PHY rate
//!   216 kbps. Effective rates follow the geometric ladder
//!   `{1, k₁, k₁², …, k₁^{n−1}}` (times the max rate) assigned to clients by
//!   a random permutation; erasure probability `p = 0.1` for all links
//!   (constant-failure rate adaptation).
//! - Compute: MAC rates follow the ladder `{1, k₂, …}` with max
//!   3.072·10⁶ MAC/s, `α = 2`; the data-point rate `μ_j` divides the MAC
//!   rate by the MACs per point of the regression gradient (`2·q·c`).
//! - Packets carry one model/gradient: `τ_j = b / rate_j`, where `b` is
//!   the *modelled payload* of that leg — `q·c` scalars at 32 bit plus
//!   10% protocol overhead by default, shrunk per leg by the
//!   [`crate::comm::PayloadModel`] byte scales when a `[comm]` codec is
//!   active (identity scales leave every τ bit-untouched).
//! - The MEC server's computing unit has dedicated, reliable resources
//!   (`P(T_C ≤ t) = 1` in §V-A — we model `p = 0` with server-grade rates).

//! Fleets beyond the paper's scale live in [`FleetShards`]: a sharded,
//! lazily-materialised store whose per-client parameters are a pure
//! function of `(seed, global index)`, so a million-client fleet costs
//! memory only for the shards a round's sampled roster actually touches
//! (see [`participation`]).

pub mod participation;

pub use participation::{
    AggregationMode, ParticipationSampler, ParticipationSpec, PARTICIPATION_STREAM_TAG,
};

use crate::delay::asymmetric::AsymNodeParams;
use crate::delay::NodeParams;
use crate::rng::Rng;

/// Fleet-wide asymmetric-link overrides (the `[fleet]` config section;
/// paper footnote 1's non-reciprocal generalisation): per-leg multipliers
/// on the §V-A τ ladder plus per-leg erasure probabilities replacing the
/// reciprocal `p`. `Default` is the reciprocal-equivalent setting (unit
/// multipliers, the paper's `p = 0.1` on both legs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AsymLinkSpec {
    /// Downlink packet-time multiplier applied to each client's ladder τ.
    pub tau_down: f64,
    /// Uplink packet-time multiplier.
    pub tau_up: f64,
    /// Downlink erasure probability (replaces the symmetric `p`).
    pub p_down: f64,
    /// Uplink erasure probability.
    pub p_up: f64,
}

impl Default for AsymLinkSpec {
    fn default() -> Self {
        AsymLinkSpec { tau_down: 1.0, tau_up: 1.0, p_down: 0.1, p_up: 0.1 }
    }
}

impl AsymLinkSpec {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.tau_down > 0.0) || !(self.tau_up > 0.0) {
            return Err(format!(
                "tau_down/tau_up must be > 0 multipliers, got {}/{}",
                self.tau_down, self.tau_up
            ));
        }
        if !(0.0..1.0).contains(&self.p_down) || !(0.0..1.0).contains(&self.p_up) {
            return Err(format!(
                "p_down/p_up must be in [0,1), got {}/{}",
                self.p_down, self.p_up
            ));
        }
        Ok(())
    }
}

/// Knobs of the §V-A fleet; `Default` is the paper's exact setting except
/// for `n`/`q`/`c`, which come from the experiment config.
#[derive(Clone, Copy, Debug)]
pub struct FleetSpec {
    /// Asymmetric downlink/uplink overrides (`None` = the paper's
    /// reciprocal links; see [`AsymLinkSpec`]).
    pub asym: Option<AsymLinkSpec>,
    pub n: usize,
    /// RFF dimension q (packet payload is the q×c model/gradient).
    pub q: usize,
    /// Number of classes c.
    pub c: usize,
    /// Link-rate ladder ratio k₁.
    pub k1: f64,
    /// MAC-rate ladder ratio k₂.
    pub k2: f64,
    /// Max effective PHY information rate in bit/s (3 LTE resource blocks).
    pub max_rate_bps: f64,
    /// Max MAC rate in MAC/s.
    pub max_mac_rate: f64,
    /// Compute/memory ratio α (same for all clients in §V-A).
    pub alpha: f64,
    /// Link erasure probability (same for all clients in §V-A).
    pub p: f64,
    /// Protocol overhead fraction (10%).
    pub overhead: f64,
    /// Bits per scalar (32).
    pub bits_per_scalar: f64,
    /// Downlink θ byte scale from the `[comm]` payload model (1.0 = the
    /// historical fixed payload, bit-identical pricing).
    pub payload_down_scale: f64,
    /// Uplink gradient byte scale (see [`crate::comm::PayloadModel`]).
    pub payload_up_scale: f64,
    /// One-shot parity upload byte scale.
    pub payload_parity_scale: f64,
}

impl FleetSpec {
    pub fn paper(n: usize, q: usize, c: usize) -> Self {
        FleetSpec {
            asym: None,
            n,
            q,
            c,
            k1: 0.95,
            k2: 0.8,
            max_rate_bps: 216_000.0,
            max_mac_rate: 3.072e6,
            alpha: 2.0,
            p: 0.1,
            overhead: 0.1,
            bits_per_scalar: 32.0,
            payload_down_scale: 1.0,
            payload_up_scale: 1.0,
            payload_parity_scale: 1.0,
        }
    }

    /// Fold a resolved `[comm]` payload model into the fleet's per-leg
    /// pricing. Identity scales (codec `none`) change nothing — every τ
    /// and every seeded history stays bit-identical.
    pub fn apply_payload(&mut self, model: &crate::comm::PayloadModel) {
        self.payload_down_scale = model.down_scale;
        self.payload_up_scale = model.up_scale;
        self.payload_parity_scale = model.parity_scale;
    }

    /// Whether the payload model prices the two data legs differently
    /// from the historical fixed payload (drives the allocation
    /// optimizer's switch to per-leg surrogates).
    pub fn payload_scaled(&self) -> bool {
        self.payload_down_scale != 1.0 || self.payload_up_scale != 1.0
    }

    /// Packet size in bits for one model or gradient transfer (q·c scalars
    /// plus protocol overhead).
    pub fn packet_bits(&self) -> f64 {
        (self.q * self.c) as f64 * self.bits_per_scalar * (1.0 + self.overhead)
    }

    /// MACs needed per data point of the regression gradient
    /// (`X̂θ` then `X̂ᵀR`: 2·q·c multiply–accumulates per row).
    pub fn macs_per_point(&self) -> f64 {
        2.0 * (self.q * self.c) as f64
    }

    /// One-time parity upload time for `u` parity rows of width `q + c`
    /// over client `j`'s uplink (expected retransmissions included) —
    /// the Fig. 4(a) inset overhead. A `[comm]` payload model scales the
    /// modelled parity bytes; the identity scale skips the multiply so
    /// historical pricing stays bit-exact.
    pub fn parity_upload_secs(&self, client: &NodeParams, u: usize) -> f64 {
        let mut bits =
            u as f64 * (self.q + self.c) as f64 * self.bits_per_scalar * (1.0 + self.overhead);
        if self.payload_parity_scale != 1.0 {
            bits *= self.payload_parity_scale;
        }
        let packets = bits / self.packet_bits();
        packets * client.tau / (1.0 - client.p)
    }

    /// Build the client fleet. Both ladders are independently permuted
    /// across clients (paper: "assign a random permutation of them").
    pub fn build_clients(&self, rng: &mut Rng) -> Vec<NodeParams> {
        let rate_perm = rng.permutation(self.n);
        let mac_perm = rng.permutation(self.n);
        (0..self.n)
            .map(|j| {
                let rate = self.max_rate_bps * self.k1.powi(rate_perm[j] as i32);
                let macs = self.max_mac_rate * self.k2.powi(mac_perm[j] as i32);
                NodeParams {
                    mu: macs / self.macs_per_point(),
                    alpha: self.alpha,
                    tau: self.packet_bits() / rate,
                    p: self.p,
                }
            })
            .collect()
    }

    /// The MEC server's computing unit: dedicated, reliable, cloud-grade
    /// (§III-C / §V-A). 100× the best client MAC rate, reliable fast link.
    pub fn build_server(&self) -> NodeParams {
        NodeParams {
            mu: 100.0 * self.max_mac_rate / self.macs_per_point(),
            alpha: 100.0,
            tau: self.packet_bits() / (100.0 * self.max_rate_bps),
            p: 0.0,
        }
    }

    /// Per-leg link model for one already-built node — the per-node body
    /// of [`FleetSpec::build_links`], shared with the sharded mega-fleet
    /// store ([`FleetShards`]). With `asym = None` the node keeps
    /// reciprocal links (`τ_d = τ_u = τ`, `p_d = p_u = p`), which samples
    /// bit-identically to the base [`NodeParams`] model; with overrides,
    /// the §V-A τ ladder is scaled per leg and the per-leg erasure
    /// probabilities replace the symmetric `p`. A `[comm]` payload model
    /// then scales each leg's τ by its modelled byte ratio (τ = b/rate,
    /// so bytes and packet time are proportional); the identity scales
    /// skip the multiply, keeping historical fleets bit-identical.
    pub fn link_of(&self, c: &NodeParams) -> AsymNodeParams {
        let mut link = match self.asym {
            None => AsymNodeParams::symmetric(c),
            Some(a) => AsymNodeParams {
                mu: c.mu,
                alpha: c.alpha,
                tau_down: c.tau * a.tau_down,
                tau_up: c.tau * a.tau_up,
                p_down: a.p_down,
                p_up: a.p_up,
            },
        };
        if self.payload_down_scale != 1.0 {
            link.tau_down *= self.payload_down_scale;
        }
        if self.payload_up_scale != 1.0 {
            link.tau_up *= self.payload_up_scale;
        }
        link
    }

    /// Per-leg link models for an already-built fleet — the form the
    /// round timeline samples. Draws no randomness — the ladder
    /// permutation lives entirely in [`FleetSpec::build_clients`].
    pub fn build_links(&self, clients: &[NodeParams]) -> Vec<AsymNodeParams> {
        clients.iter().map(|c| self.link_of(c)).collect()
    }

    /// Ladder rung count for the mega-fleet tiling: the §V-A geometric
    /// ladder keeps its dynamic range by tiling at depth `min(n, 64)`
    /// instead of assigning a length-`n` permutation (`k₁ⁿ` underflows
    /// every rate to zero once `n ≫ 10³`). A tiling, not an exact
    /// permutation: each rung repeats ~`n / depth` times across the
    /// fleet.
    pub fn ladder_depth(&self) -> usize {
        self.n.min(64).max(1)
    }

    /// Node parameters for global client index `g` of a ladder-tiled
    /// mega-fleet — a pure function of `(seed, g)` via the counter-based
    /// [`Rng::indexed`] split, so any client is constructible in O(1),
    /// independent of shard size, build order and fleet size. The rate
    /// and MAC rungs are drawn independently, mirroring the two
    /// independent permutations of [`FleetSpec::build_clients`].
    pub fn node_at(&self, seed: u64, g: usize) -> NodeParams {
        let mut rng = Rng::indexed(seed, g as u64);
        let depth = self.ladder_depth();
        let rate = self.max_rate_bps * self.k1.powi(rng.next_below(depth) as i32);
        let macs = self.max_mac_rate * self.k2.powi(rng.next_below(depth) as i32);
        NodeParams {
            mu: macs / self.macs_per_point(),
            alpha: self.alpha,
            tau: self.packet_bits() / rate,
            p: self.p,
        }
    }
}

/// Sharded fleet store for N = 10^5–10^6 clients: per-client link models
/// held in contiguous per-shard arenas that are materialised *lazily*, so
/// a sampled round touches (and pays memory for) only the shards its
/// roster lands in — never a monolithic length-N `Vec` rebuild.
///
/// Two sources:
/// * [`FleetShards::from_links`] — the fleet *is* the experiment's base
///   fleet (`N == cfg.clients`); `link(g)` returns the canonical base
///   link bit-for-bit, so sampled views agree exactly with
///   [`FleetView::reset_from`] over the same clients.
/// * [`FleetShards::ladder`] — a ladder-tiled mega-fleet
///   (`[fleet] n > clients`); shard arenas are filled from
///   [`FleetSpec::node_at`], whose parameters depend only on
///   `(seed, g)`, making the fleet identical for every `shard_size`.
#[derive(Clone, Debug)]
pub struct FleetShards {
    n: usize,
    shard_size: usize,
    source: ShardSource,
    /// Lazily-built arenas; `shards[s]` covers global indices
    /// `s·shard_size .. min((s+1)·shard_size, n)`.
    shards: Vec<Option<Box<[AsymNodeParams]>>>,
}

#[derive(Clone, Debug)]
enum ShardSource {
    /// The experiment's base links, indexed directly (no arenas).
    Links(Vec<AsymNodeParams>),
    /// Ladder-tiled mega-fleet, derived per shard on first touch.
    Ladder { spec: FleetSpec, seed: u64 },
}

impl FleetShards {
    /// Store over the experiment's base fleet (`N == links.len()`);
    /// `link(g)` is bit-identical to `links[g]` and no arena is ever
    /// built.
    pub fn from_links(links: &[AsymNodeParams]) -> Self {
        FleetShards {
            n: links.len(),
            shard_size: links.len().max(1),
            source: ShardSource::Links(links.to_vec()),
            shards: Vec::new(),
        }
    }

    /// Ladder-tiled mega-fleet of `spec.n` clients in arenas of
    /// `shard_size` (`[fleet] shard_size`); `seed` pins the per-client
    /// parameter draws.
    pub fn ladder(spec: FleetSpec, seed: u64, shard_size: usize) -> Self {
        assert!(spec.n > 0, "fleet must have at least one client");
        assert!(shard_size > 0, "shard_size must be >= 1");
        let num = spec.n.div_ceil(shard_size);
        FleetShards {
            n: spec.n,
            shard_size,
            source: ShardSource::Ladder { spec, seed },
            shards: vec![None; num],
        }
    }

    /// Fleet size N.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    /// Arenas materialised so far (telemetry: a sampled run should touch
    /// ~`K·rounds/shard_size` of the `ceil(N/shard_size)` shards).
    pub fn built_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.is_some()).count()
    }

    pub fn num_shards(&self) -> usize {
        match self.source {
            ShardSource::Links(_) => 1,
            ShardSource::Ladder { .. } => self.shards.len(),
        }
    }

    /// The per-leg link model of global client `g`, materialising its
    /// shard on first touch (`&mut` only for that lazy build — the
    /// returned value is a pure function of the store's construction).
    pub fn link(&mut self, g: usize) -> AsymNodeParams {
        assert!(g < self.n, "client {g} of {}", self.n);
        match &mut self.source {
            ShardSource::Links(links) => links[g],
            ShardSource::Ladder { spec, seed } => {
                let s = g / self.shard_size;
                let arena = self.shards[s].get_or_insert_with(|| {
                    let lo = s * self.shard_size;
                    let hi = ((s + 1) * self.shard_size).min(spec.n);
                    (lo..hi).map(|i| spec.link_of(&spec.node_at(*seed, i))).collect()
                });
                arena[g - s * self.shard_size]
            }
        }
    }

    /// Materialise every shard now (benches/tests that gate warm-round
    /// allocations use this to reach steady state up front; training
    /// leaves builds lazy).
    pub fn build_all(&mut self) {
        for g in (0..self.n).step_by(self.shard_size) {
            let _ = self.link(g);
        }
    }
}

/// The round's working copy of the fleet — what a
/// [`crate::sim::scenario::Scenario`] modulates before the timeline
/// samples delays. The engine resets it from the base fleet at the top of
/// every round ([`FleetView::reset_from`], allocation-free once warm), so
/// scenarios mutate freely: scale node parameters, mark clients
/// unavailable, slow the server — without touching the experiment's base
/// topology.
#[derive(Clone, Debug)]
pub struct FleetView {
    /// Per-client per-leg node models, this round.
    pub clients: Vec<AsymNodeParams>,
    /// Per-client availability; an unavailable client samples no delay
    /// and carries `T_j = ∞` in the round's delays.
    pub available: Vec<bool>,
    /// The MEC computing unit, this round.
    pub server: NodeParams,
}

impl FleetView {
    /// A view initialised to the base fleet, everyone available.
    pub fn from_base(links: &[AsymNodeParams], server: NodeParams) -> Self {
        let mut view = FleetView {
            clients: Vec::with_capacity(links.len()),
            available: Vec::with_capacity(links.len()),
            server,
        };
        view.reset_from(links, server);
        view
    }

    /// Reset to the base fleet (called at the top of every round). Clears
    /// and refills in place — zero allocations once the buffers reached
    /// fleet size.
    pub fn reset_from(&mut self, links: &[AsymNodeParams], server: NodeParams) {
        self.clients.clear();
        self.clients.extend_from_slice(links);
        self.available.clear();
        self.available.resize(links.len(), true);
        self.server = server;
    }

    /// Reset to a sampled roster: view slot `i` becomes global client
    /// `roster[i]` of the sharded fleet, everyone available. O(K) per
    /// round — only the participating clients are touched, never the full
    /// fleet — and allocation-free once the buffers reached roster size
    /// and the touched shards are materialised.
    pub fn reset_roster(
        &mut self,
        shards: &mut FleetShards,
        roster: &[u32],
        server: NodeParams,
    ) {
        self.clients.clear();
        self.clients.extend(roster.iter().map(|&g| shards.link(g as usize)));
        self.available.clear();
        self.available.resize(roster.len(), true);
        self.server = server;
    }

    /// Number of clients in the fleet.
    pub fn len(&self) -> usize {
        self.clients.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packet_bits_paper_scale() {
        let s = FleetSpec::paper(30, 2000, 10);
        // 2000*10*32*1.1 = 704_000 bits
        assert!((s.packet_bits() - 704_000.0).abs() < 1e-6);
    }

    #[test]
    fn fleet_has_ladder_rates() {
        let s = FleetSpec::paper(30, 2000, 10);
        let clients = s.build_clients(&mut Rng::seed_from(1));
        assert_eq!(clients.len(), 30);
        // fastest link tau = b / 216k; slowest = b / (216k * .95^29)
        let taus: Vec<f64> = clients.iter().map(|c| c.tau).collect();
        let min_tau = taus.iter().cloned().fold(f64::INFINITY, f64::min);
        let max_tau = taus.iter().cloned().fold(0.0, f64::max);
        assert!((min_tau - s.packet_bits() / 216_000.0).abs() < 1e-9);
        let expect_max = s.packet_bits() / (216_000.0 * 0.95f64.powi(29));
        assert!((max_tau - expect_max).abs() < 1e-9);
    }

    #[test]
    fn fleet_mu_ladder_and_params() {
        let s = FleetSpec::paper(30, 2000, 10);
        let clients = s.build_clients(&mut Rng::seed_from(2));
        let mus: Vec<f64> = clients.iter().map(|c| c.mu).collect();
        let max_mu = mus.iter().cloned().fold(0.0, f64::max);
        assert!((max_mu - 3.072e6 / 40_000.0).abs() < 1e-9); // 76.8 pts/s
        for c in &clients {
            assert_eq!(c.alpha, 2.0);
            assert_eq!(c.p, 0.1);
            c.validate().unwrap();
        }
    }

    #[test]
    fn permutation_is_seed_dependent_but_ladder_preserved() {
        let s = FleetSpec::paper(10, 100, 10);
        let a = s.build_clients(&mut Rng::seed_from(3));
        let b = s.build_clients(&mut Rng::seed_from(4));
        let mut ra: Vec<u64> = a.iter().map(|c| c.tau.to_bits()).collect();
        let mut rb: Vec<u64> = b.iter().map(|c| c.tau.to_bits()).collect();
        assert_ne!(ra, rb, "different seeds should permute differently");
        ra.sort_unstable();
        rb.sort_unstable();
        assert_eq!(ra, rb, "the ladder multiset is seed-independent");
    }

    #[test]
    fn server_is_fast_and_reliable() {
        let s = FleetSpec::paper(30, 2000, 10);
        let srv = s.build_server();
        assert_eq!(srv.p, 0.0);
        assert!(srv.mu > 100.0 * 76.0);
        srv.validate().unwrap();
    }

    #[test]
    fn build_links_symmetric_matches_base_and_asym_scales_ladder() {
        let mut spec = FleetSpec::paper(8, 100, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(6));
        // Reciprocal default: per-leg model mirrors the base exactly.
        for (l, c) in spec.build_links(&clients).iter().zip(&clients) {
            assert_eq!(l.tau_down.to_bits(), c.tau.to_bits());
            assert_eq!(l.tau_up.to_bits(), c.tau.to_bits());
            assert_eq!(l.p_down, c.p);
            assert_eq!(l.p_up, c.p);
            assert_eq!(l.mu, c.mu);
            l.validate().unwrap();
        }
        // Asymmetric overrides: the ladder τ is scaled per leg, p replaced.
        spec.asym = Some(AsymLinkSpec { tau_down: 1.0, tau_up: 2.5, p_down: 0.05, p_up: 0.2 });
        for (l, c) in spec.build_links(&clients).iter().zip(&clients) {
            assert!((l.tau_down - c.tau).abs() < 1e-12);
            assert!((l.tau_up - 2.5 * c.tau).abs() < 1e-12);
            assert_eq!((l.p_down, l.p_up), (0.05, 0.2));
            l.validate().unwrap();
        }
    }

    #[test]
    fn asym_link_spec_validates() {
        assert!(AsymLinkSpec::default().validate().is_ok());
        let ok = AsymLinkSpec::default();
        assert!(AsymLinkSpec { tau_down: 0.0, ..ok }.validate().is_err());
        assert!(AsymLinkSpec { tau_up: -1.0, ..ok }.validate().is_err());
        assert!(AsymLinkSpec { p_down: 1.0, ..ok }.validate().is_err());
        assert!(AsymLinkSpec { p_up: -0.1, ..ok }.validate().is_err());
    }

    #[test]
    fn fleet_view_resets_to_base_without_growing() {
        let spec = FleetSpec::paper(5, 100, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(9));
        let links = spec.build_links(&clients);
        let server = spec.build_server();
        let mut view = FleetView::from_base(&links, server);
        assert_eq!(view.len(), 5);
        assert!(!view.is_empty());
        assert!(view.available.iter().all(|&a| a));
        // Scenario-style mutation…
        view.clients[2].mu *= 0.25;
        view.available[4] = false;
        // …is fully undone by the per-round reset.
        view.reset_from(&links, server);
        assert_eq!(view.clients[2].mu, links[2].mu);
        assert!(view.available[4]);
        assert!(view.clients.capacity() >= 5);
    }

    #[test]
    fn fleet_shards_from_links_indexes_the_base_fleet_bitwise() {
        let spec = FleetSpec::paper(6, 100, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(11));
        let links = spec.build_links(&clients);
        let mut shards = FleetShards::from_links(&links);
        assert_eq!(shards.len(), 6);
        for (g, l) in links.iter().enumerate() {
            let got = shards.link(g);
            assert_eq!(got.tau_down.to_bits(), l.tau_down.to_bits());
            assert_eq!(got.mu.to_bits(), l.mu.to_bits());
        }
        assert_eq!(shards.built_shards(), 0, "base links need no arenas");
    }

    #[test]
    fn fleet_shards_ladder_is_lazy_and_shard_size_invariant() {
        let spec = FleetSpec::paper(1000, 100, 10);
        let mut a = FleetShards::ladder(spec, 0xF1EE7, 64);
        let mut b = FleetShards::ladder(spec, 0xF1EE7, 256);
        assert_eq!(a.num_shards(), 16);
        assert_eq!(b.num_shards(), 4);
        assert_eq!(a.built_shards(), 0);
        // Touching one client builds exactly its shard…
        let _ = a.link(700);
        assert_eq!(a.built_shards(), 1);
        // …and the parameters depend only on (seed, g), not shard_size.
        for g in [0usize, 63, 64, 700, 999] {
            let (la, lb) = (a.link(g), b.link(g));
            assert_eq!(la.tau_down.to_bits(), lb.tau_down.to_bits());
            assert_eq!(la.mu.to_bits(), lb.mu.to_bits());
            la.validate().unwrap();
        }
        // A different seed draws a different fleet.
        let mut c = FleetShards::ladder(spec, 0xF1EE8, 64);
        assert!((0..100).any(|g| c.link(g).mu.to_bits() != b.link(g).mu.to_bits()));
        // Every rung stays in the tiled ladder's finite range.
        let depth = spec.ladder_depth();
        assert_eq!(depth, 64);
        let min_mu = spec.max_mac_rate * spec.k2.powi(depth as i32 - 1) / spec.macs_per_point();
        for g in 0..1000 {
            assert!(b.link(g).mu >= min_mu - 1e-9);
        }
        b.build_all();
        assert_eq!(b.built_shards(), 4);
    }

    #[test]
    fn fleet_view_resets_to_roster_slots() {
        let spec = FleetSpec::paper(10, 100, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(21));
        let links = spec.build_links(&clients);
        let server = spec.build_server();
        let mut shards = FleetShards::from_links(&links);
        let mut view = FleetView::from_base(&links, server);
        let roster: Vec<u32> = vec![1, 4, 7];
        view.reset_roster(&mut shards, &roster, server);
        assert_eq!(view.len(), 3);
        assert!(view.available.iter().all(|&a| a));
        for (slot, &g) in roster.iter().enumerate() {
            assert_eq!(view.clients[slot].mu.to_bits(), links[g as usize].mu.to_bits());
        }
        // The identity roster reproduces reset_from exactly.
        let identity: Vec<u32> = (0..10).collect();
        view.reset_roster(&mut shards, &identity, server);
        let mut full = FleetView::from_base(&links, server);
        full.reset_from(&links, server);
        assert_eq!(view.len(), full.len());
        for (a, b) in view.clients.iter().zip(&full.clients) {
            assert_eq!(a.tau_up.to_bits(), b.tau_up.to_bits());
        }
    }

    #[test]
    fn parity_upload_scales_with_u() {
        let s = FleetSpec::paper(30, 200, 10);
        let c = NodeParams { mu: 1.0, alpha: 2.0, tau: 2.0, p: 0.1 };
        let t1 = s.parity_upload_secs(&c, 100);
        let t2 = s.parity_upload_secs(&c, 200);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn payload_scales_reprice_the_legs_and_identity_is_bit_exact() {
        use crate::comm::{CodecSpec, PayloadModel, PayloadSpec, ScaleSpec};
        let mut spec = FleetSpec::paper(8, 100, 10);
        let clients = spec.build_clients(&mut Rng::seed_from(30));
        let base_links = spec.build_links(&clients);
        // The identity model leaves every leg bit-untouched.
        let id = PayloadModel::new(100, 10, CodecSpec::None, PayloadSpec::Auto, spec.overhead);
        spec.apply_payload(&id);
        assert!(!spec.payload_scaled());
        for (l, b) in spec.build_links(&clients).iter().zip(&base_links) {
            assert_eq!(l.tau_down.to_bits(), b.tau_down.to_bits());
            assert_eq!(l.tau_up.to_bits(), b.tau_up.to_bits());
        }
        let c0 = clients[0];
        let parity_base = spec.parity_upload_secs(&c0, 64);
        // A q8 payload shrinks only the uplink (and the parity upload).
        let q8 = PayloadModel::new(
            100,
            10,
            CodecSpec::Q8 { scale: ScaleSpec::Auto },
            PayloadSpec::Auto,
            spec.overhead,
        );
        spec.apply_payload(&q8);
        assert!(spec.payload_scaled());
        for (l, b) in spec.build_links(&clients).iter().zip(&base_links) {
            assert_eq!(l.tau_down.to_bits(), b.tau_down.to_bits(), "theta stays 32-bit");
            assert!((l.tau_up / b.tau_up - q8.up_scale).abs() < 1e-12);
            l.validate().unwrap();
        }
        let parity_q8 = spec.parity_upload_secs(&c0, 64);
        assert!((parity_q8 / parity_base - q8.parity_scale).abs() < 1e-12);
        // The mega-fleet ladder path prices through the same link_of body.
        let mut shards = FleetShards::ladder(spec, 0xF1EE7, 64);
        let mut plain = spec;
        plain.payload_down_scale = 1.0;
        plain.payload_up_scale = 1.0;
        plain.payload_parity_scale = 1.0;
        let mut base_shards = FleetShards::ladder(plain, 0xF1EE7, 64);
        for g in [0usize, 5, 63] {
            let (a, b) = (shards.link(g), base_shards.link(g));
            assert_eq!(a.tau_down.to_bits(), b.tau_down.to_bits());
            assert!((a.tau_up / b.tau_up - q8.up_scale).abs() < 1e-12);
        }
    }
}
