//! Per-round sampled participation for mega-fleets (`[fleet]
//! participation`).
//!
//! The paper's experiments run every client every round; a
//! production-scale fleet of 10^5–10^6 clients cannot (and, per the
//! sampled-participation convergence analyses of arXiv:2201.10092, need
//! not). This module provides the *scheme-independent* participation
//! layer: before any scheme sees the round, the engine draws a **roster**
//! — the sorted global indices of the K clients participating this round
//! — and materialises the round's [`crate::topology::FleetView`] over the
//! roster only. Every scheme run on a session therefore observes the
//! identical participation realisation, exactly as scenarios already
//! guarantee for network behaviour.
//!
//! Determinism contract: round `r`'s roster is a pure function of
//! `(stream base, r)` through the counter-based [`Rng::indexed`] split —
//! no state is carried between rounds, no draw depends on the fleet's
//! shard layout — so the realisation is reproducible at any fleet size
//! and independent of shard count, thread count and SIMD policy.
//! `full` participation (the default) draws nothing from the stream and
//! is bit-identical to the pre-participation engine.

use crate::rng::Rng;

/// Stream label for the engine's participation RNG split (disjoint from
/// the scheme tags, the scenario stream
/// [`crate::sim::scenario::SCENARIO_STREAM_TAG`] and the `FedSetup`
/// streams by construction).
pub const PARTICIPATION_STREAM_TAG: u64 = 0x9A47_71C1;

/// Who participates each round (`[fleet] participation` / CLI
/// `--participation`): every client, or a fresh uniform sample of `k`
/// without replacement per round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParticipationSpec {
    /// Every client, every round — the paper's setting and the default;
    /// bit-identical to the pre-participation engine.
    Full,
    /// A fresh uniform sample of `k` distinct clients per round
    /// (`sample:k=`). `sample:k=N` realises the identity roster and
    /// reproduces `full` bit-for-bit.
    Sample { k: usize },
}

impl Default for ParticipationSpec {
    fn default() -> Self {
        ParticipationSpec::Full
    }
}

impl ParticipationSpec {
    /// Parse a participation spec string: `full` | `sample:k=31`.
    pub fn parse(s: &str) -> Result<ParticipationSpec, String> {
        let (name, params) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p.trim())),
            None => (s.trim(), None),
        };
        let kvs = |allowed: &[(&str, f64)]| -> Result<Vec<f64>, String> {
            let mut vals: Vec<f64> = allowed.iter().map(|&(_, d)| d).collect();
            if let Some(ps) = params {
                for kv in ps.split(',').filter(|t| !t.trim().is_empty()) {
                    let (k, v) = kv
                        .split_once('=')
                        .ok_or_else(|| format!("{name:?}: expected key=value, got {kv:?}"))?;
                    let pos = allowed
                        .iter()
                        .position(|&(a, _)| a == k.trim())
                        .ok_or_else(|| {
                            let keys: Vec<&str> = allowed.iter().map(|&(a, _)| a).collect();
                            format!(
                                "{name:?}: unknown parameter {:?} (expected {})",
                                k.trim(),
                                keys.join(", ")
                            )
                        })?;
                    vals[pos] = v
                        .trim()
                        .parse::<f64>()
                        .map_err(|_| format!("{name:?}: {} must be a number, got {v:?}", k.trim()))?;
                }
            }
            Ok(vals)
        };
        match name {
            "full" => {
                kvs(&[])?;
                Ok(ParticipationSpec::Full)
            }
            "sample" => {
                let v = kvs(&[("k", 0.0)])?;
                if v[0].fract() != 0.0 || v[0] < 0.0 {
                    return Err(format!("\"sample\": k must be a non-negative integer, got {}", v[0]));
                }
                Ok(ParticipationSpec::Sample { k: v[0] as usize })
            }
            other => Err(format!(
                "unknown participation {other:?} (expected one of full, sample:k=)"
            )),
        }
    }

    /// Range checks against the fleet size `n` (the error is prefixed with
    /// its config location by the conf loader).
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if let ParticipationSpec::Sample { k } = *self {
            if k == 0 || k > n {
                return Err(format!(
                    "sample: k={k} out of range (expected one of 1..={n} for the {n}-client fleet)"
                ));
            }
        }
        Ok(())
    }

    /// Canonical spec string (round-trips through [`ParticipationSpec::parse`]).
    pub fn label(&self) -> String {
        match *self {
            ParticipationSpec::Full => "full".into(),
            ParticipationSpec::Sample { k } => format!("sample:k={k}"),
        }
    }

    /// Roster size on an `n`-client fleet.
    pub fn k(&self, n: usize) -> usize {
        match *self {
            ParticipationSpec::Full => n,
            ParticipationSpec::Sample { k } => k,
        }
    }
}

impl std::str::FromStr for ParticipationSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        ParticipationSpec::parse(s)
    }
}

/// How the engine folds the round's planned gradients (`[fleet]
/// aggregation` / CLI `--aggregation`): a flat sequential fold, or
/// per-shard partial sums on the worker pool before the root fold — the
/// edge-aggregator tree of arXiv:2007.03273, flattened to two levels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregationMode {
    /// Sequential fold in plan order (the historical engine fold;
    /// default).
    Flat,
    /// Hierarchical two-level fold (`hier:shard=`): plan-order groups of
    /// `shard` consecutive requests each fold sequentially into a partial
    /// sum (groups run concurrently on the worker pool), then the root
    /// folds the partials in group order. Both levels are sequential in a
    /// documented order, so the result is bit-identical for every thread
    /// count.
    Hier { shard: usize },
}

impl Default for AggregationMode {
    fn default() -> Self {
        AggregationMode::Flat
    }
}

impl AggregationMode {
    /// Parse an aggregation spec string: `flat` | `hier:shard=256`.
    pub fn parse(s: &str) -> Result<AggregationMode, String> {
        let (name, params) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p.trim())),
            None => (s.trim(), None),
        };
        match name {
            "flat" => match params {
                None => Ok(AggregationMode::Flat),
                Some(p) => Err(format!("\"flat\": takes no parameters, got {p:?}")),
            },
            "hier" => {
                let kv = params.unwrap_or("");
                let v = kv
                    .strip_prefix("shard=")
                    .ok_or_else(|| format!("\"hier\": expected shard=, got {kv:?}"))?;
                let shard = v
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| format!("\"hier\": shard must be a positive integer, got {v:?}"))?;
                if shard == 0 {
                    return Err("\"hier\": shard must be >= 1, got 0".into());
                }
                Ok(AggregationMode::Hier { shard })
            }
            other => Err(format!(
                "unknown aggregation {other:?} (expected one of flat, hier:shard=)"
            )),
        }
    }

    /// Canonical spec string (round-trips through [`AggregationMode::parse`]).
    pub fn label(&self) -> String {
        match *self {
            AggregationMode::Flat => "flat".into(),
            AggregationMode::Hier { shard } => format!("hier:shard={shard}"),
        }
    }
}

impl std::str::FromStr for AggregationMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        AggregationMode::parse(s)
    }
}

/// Draws each round's roster: the sorted global indices of the clients
/// participating that round.
///
/// The sampler owns an identity pool of all `n` indices (built once) and
/// runs a *partial* Fisher–Yates over it per draw — `k` swaps forward,
/// recorded, then unwound — so a draw costs O(k log k) (the log from the
/// final roster sort) independent of `n`, allocates nothing once warm,
/// and leaves the pool in the identity state that makes round `r`'s
/// roster a pure function of `(base, r)` via [`Rng::indexed`].
#[derive(Clone, Debug)]
pub struct ParticipationSampler {
    spec: ParticipationSpec,
    n: usize,
    base: u64,
    pool: Vec<u32>,
    swaps: Vec<(u32, u32)>,
    roster: Vec<u32>,
}

impl ParticipationSampler {
    /// Sampler over an `n`-client fleet; `base` seeds the indexable
    /// per-round streams (the engine derives it from the experiment seed
    /// through the [`PARTICIPATION_STREAM_TAG`] split).
    pub fn new(spec: ParticipationSpec, n: usize, base: u64) -> Self {
        assert!(n > 0, "participation over an empty fleet");
        spec.validate(n).expect("validated by the config loader");
        let k = spec.k(n);
        ParticipationSampler {
            spec,
            n,
            base,
            // `full` never swaps, so it skips the O(n) pool too.
            pool: match spec {
                ParticipationSpec::Full => Vec::new(),
                ParticipationSpec::Sample { .. } => (0..n as u32).collect(),
            },
            swaps: Vec::with_capacity(k),
            roster: Vec::with_capacity(n.max(k)),
        }
    }

    pub fn spec(&self) -> ParticipationSpec {
        self.spec
    }

    /// Roster size (clients per round).
    pub fn k(&self) -> usize {
        self.spec.k(self.n)
    }

    /// Fleet size `n`.
    pub fn fleet_size(&self) -> usize {
        self.n
    }

    /// Draw round `round`'s roster: `k` distinct global client indices,
    /// uniform without replacement, sorted ascending. Allocation-free
    /// once warm; see the struct docs for the determinism contract.
    pub fn draw(&mut self, round: usize) -> &[u32] {
        self.roster.clear();
        match self.spec {
            ParticipationSpec::Full => {
                self.roster.extend(0..self.n as u32);
            }
            ParticipationSpec::Sample { k } => {
                let mut rng = Rng::indexed(self.base, round as u64);
                self.swaps.clear();
                for i in 0..k {
                    let j = i + rng.next_below(self.n - i);
                    self.swaps.push((i as u32, j as u32));
                    self.pool.swap(i, j);
                    self.roster.push(self.pool[i]);
                }
                // Unwind the swaps (reverse order) to restore the
                // identity pool before the next draw.
                for &(i, j) in self.swaps.iter().rev() {
                    self.pool.swap(i as usize, j as usize);
                }
                self.roster.sort_unstable();
            }
        }
        &self.roster
    }

    /// The most recent roster (empty before the first draw).
    pub fn roster(&self) -> &[u32] {
        &self.roster
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_round_trips() {
        assert_eq!(ParticipationSpec::parse("full").unwrap(), ParticipationSpec::Full);
        assert_eq!(
            ParticipationSpec::parse("sample:k=31").unwrap(),
            ParticipationSpec::Sample { k: 31 }
        );
        for s in ["full", "sample:k=31"] {
            let spec: ParticipationSpec = s.parse().unwrap();
            assert_eq!(spec.label(), s);
        }
    }

    #[test]
    fn spec_rejects_garbage_with_expected_one_of() {
        let e = ParticipationSpec::parse("partial").unwrap_err();
        assert!(e.contains("expected one of full, sample:k="), "{e}");
        let e = ParticipationSpec::parse("sample:j=3").unwrap_err();
        assert!(e.contains("unknown parameter"), "{e}");
        assert!(ParticipationSpec::parse("sample:k=1.5").is_err());
        assert!(ParticipationSpec::parse("sample:k").is_err());
    }

    #[test]
    fn spec_validates_k_against_fleet_size() {
        assert!(ParticipationSpec::Full.validate(3).is_ok());
        assert!(ParticipationSpec::Sample { k: 3 }.validate(3).is_ok());
        let e = ParticipationSpec::Sample { k: 0 }.validate(3).unwrap_err();
        assert!(e.contains("expected one of 1..=3"), "{e}");
        let e = ParticipationSpec::Sample { k: 4 }.validate(3).unwrap_err();
        assert!(e.contains("k=4") && e.contains("1..=3"), "{e}");
    }

    #[test]
    fn aggregation_parses_and_round_trips() {
        assert_eq!(AggregationMode::parse("flat").unwrap(), AggregationMode::Flat);
        assert_eq!(
            AggregationMode::parse("hier:shard=256").unwrap(),
            AggregationMode::Hier { shard: 256 }
        );
        for s in ["flat", "hier:shard=8"] {
            let m: AggregationMode = s.parse().unwrap();
            assert_eq!(m.label(), s);
        }
        assert!(AggregationMode::parse("tree").unwrap_err().contains("expected one of"));
        assert!(AggregationMode::parse("hier:shard=0").is_err());
        assert!(AggregationMode::parse("hier:depth=2").is_err());
    }

    #[test]
    fn full_roster_is_identity() {
        let mut s = ParticipationSampler::new(ParticipationSpec::Full, 5, 7);
        assert_eq!(s.draw(0), &[0, 1, 2, 3, 4]);
        assert_eq!(s.k(), 5);
    }

    #[test]
    fn sample_rosters_are_sorted_distinct_and_in_range() {
        let mut s = ParticipationSampler::new(ParticipationSpec::Sample { k: 8 }, 100, 1);
        for r in 0..50 {
            let roster = s.draw(r).to_vec();
            assert_eq!(roster.len(), 8);
            assert!(roster.windows(2).all(|w| w[0] < w[1]), "{roster:?}");
            assert!(roster.iter().all(|&g| (g as usize) < 100));
        }
    }

    #[test]
    fn draws_are_counter_based_pure_functions_of_the_round() {
        // Drawing rounds out of order, repeatedly, or from a fresh sampler
        // yields identical rosters: no cross-round state.
        let mut a = ParticipationSampler::new(ParticipationSpec::Sample { k: 4 }, 50, 99);
        let r7 = a.draw(7).to_vec();
        let r3 = a.draw(3).to_vec();
        assert_eq!(a.draw(7), &r7[..]);
        let mut b = ParticipationSampler::new(ParticipationSpec::Sample { k: 4 }, 50, 99);
        assert_eq!(b.draw(3), &r3[..]);
        assert_eq!(b.draw(7), &r7[..]);
        // Distinct rounds (overwhelmingly) differ.
        assert!((0..20).any(|r| a.draw(r) != &r7[..]));
    }

    #[test]
    fn sample_k_equals_n_is_the_identity_roster() {
        let mut s = ParticipationSampler::new(ParticipationSpec::Sample { k: 6 }, 6, 5);
        for r in 0..10 {
            assert_eq!(s.draw(r), &[0, 1, 2, 3, 4, 5]);
        }
    }

    #[test]
    fn sampling_is_roughly_uniform() {
        let n = 20;
        let mut counts = vec![0usize; n];
        let mut s = ParticipationSampler::new(ParticipationSpec::Sample { k: 5 }, n, 13);
        let rounds = 2000;
        for r in 0..rounds {
            for &g in s.draw(r) {
                counts[g as usize] += 1;
            }
        }
        let expect = rounds * 5 / n;
        for (g, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < 0.2 * expect as f64,
                "client {g}: {c} picks vs {expect} expected"
            );
        }
    }
}
