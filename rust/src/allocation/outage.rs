//! Outage-probability load allocation (paper §VI future work).
//!
//! The main optimizer (eq. 23) targets the *expected* aggregate return
//! `E[R(t)] = m`; here we instead pick the minimum deadline such that the
//! probability of an aggregate-return outage is bounded:
//!
//! ```text
//! min t  s.t.  P( R(t; u*, ℓ*) < (1−ε)·m ) ≤ η
//! ```
//!
//! `R(t)` is a sum of independent scaled Bernoullis (eq. 22), so the
//! outage probability is evaluated *exactly* by dynamic programming over
//! the return distribution (loads quantised to integers), and the minimum
//! deadline again falls to bisection because the outage probability is
//! non-increasing in `t` for fixed loads re-optimised per `t`.

use super::{optimal_load, NodeSpec};
use crate::numerics::bisect_min_t;

/// Exact `P(Σ_j ℓ_j·B_j < target)` for independent Bernoullis `B_j` with
/// success probabilities `probs[j]` and integer weights `loads[j]`.
///
/// DP over achievable partial sums; cost `O(n · Σℓ)` — fine for the
/// ≤31-node fleets and mini-batch-scale loads used here.
pub fn outage_probability(loads: &[u64], probs: &[f64], target: u64) -> f64 {
    assert_eq!(loads.len(), probs.len());
    if target == 0 {
        return 0.0;
    }
    // dist[s] = P(partial sum == s), truncated at `target` (everything at
    // or above target is lumped into `at_least` — it can't become an
    // outage later since sums only grow).
    let cap = target as usize;
    let mut dist = vec![0.0f64; cap];
    let mut at_least = 0.0f64;
    dist[0] = 1.0;
    for (&l, &p) in loads.iter().zip(probs) {
        if l == 0 {
            continue;
        }
        let mut next = vec![0.0f64; cap];
        let mut next_at_least = at_least; // mass already ≥ target stays
        for (s, &mass) in dist.iter().enumerate() {
            if mass == 0.0 {
                continue;
            }
            // miss
            next[s] += mass * (1.0 - p);
            // hit
            let ns = s + l as usize;
            if ns >= cap {
                next_at_least += mass * p;
            } else {
                next[ns] += mass * p;
            }
        }
        dist = next;
        at_least = next_at_least;
    }
    dist.iter().sum::<f64>().clamp(0.0, 1.0)
}

/// Result of the outage-constrained optimisation.
#[derive(Clone, Debug)]
pub struct OutageAllocation {
    pub t_star: f64,
    pub loads: Vec<f64>,
    pub outage: f64,
}

/// Minimum deadline with `P(R(t) < (1−ε)m) ≤ η`, re-optimising the Step-1
/// loads at every probed `t` (same structure as the expected-return
/// two-step solve).
pub fn solve_outage(
    nodes: &[NodeSpec],
    m: f64,
    epsilon: f64,
    eta: f64,
) -> Option<OutageAllocation> {
    assert!((0.0..1.0).contains(&epsilon) && (0.0..1.0).contains(&eta));
    let target = ((1.0 - epsilon) * m).ceil() as u64;

    let outage_at = |t: f64| -> (f64, Vec<f64>) {
        let mut loads = Vec::with_capacity(nodes.len());
        let mut int_loads = Vec::with_capacity(nodes.len());
        let mut probs = Vec::with_capacity(nodes.len());
        for n in nodes {
            let (l, _) = optimal_load(&n.params, t, n.max_load);
            let li = l.floor();
            loads.push(l);
            int_loads.push(li as u64);
            probs.push(if li > 0.0 { n.params.cdf(t, li) } else { 0.0 });
        }
        (outage_probability(&int_loads, &probs, target), loads)
    };

    // Bracket then bisect on the (non-increasing in t) outage probability.
    let t_min = nodes
        .iter()
        .map(|n| 2.0 * n.params.tau)
        .fold(f64::INFINITY, f64::min)
        .max(1e-9);
    let mut t_hi = t_min * 2.0 + 1.0;
    let mut ok = false;
    for _ in 0..96 {
        if outage_at(t_hi).0 <= eta {
            ok = true;
            break;
        }
        t_hi *= 2.0;
    }
    if !ok {
        return None;
    }
    let t_star = bisect_min_t(t_min, t_hi, 1.0 - eta, 1e-6, |t| 1.0 - outage_at(t).0)?;
    let (outage, loads) = outage_at(t_star);
    Some(OutageAllocation { t_star, loads, outage })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::NodeParams;

    #[test]
    fn outage_probability_hand_cases() {
        // Two nodes of weight 1, p = 0.5 each; target 2 ⇒ outage unless
        // both hit: 1 − 0.25 = 0.75.
        let o = outage_probability(&[1, 1], &[0.5, 0.5], 2);
        assert!((o - 0.75).abs() < 1e-12);
        // target 1 ⇒ outage only if both miss: 0.25.
        let o = outage_probability(&[1, 1], &[0.5, 0.5], 1);
        assert!((o - 0.25).abs() < 1e-12);
        // target 0 ⇒ never an outage.
        assert_eq!(outage_probability(&[1], &[0.1], 0), 0.0);
        // zero-load nodes contribute nothing.
        let o = outage_probability(&[0, 1], &[0.9, 0.5], 1);
        assert!((o - 0.5).abs() < 1e-12);
    }

    #[test]
    fn outage_matches_monte_carlo() {
        let loads = [3u64, 5, 2, 7];
        let probs = [0.9, 0.6, 0.8, 0.3];
        let target = 10u64;
        let exact = outage_probability(&loads, &probs, target);
        let mut rng = crate::rng::Rng::seed_from(5);
        let trials = 200_000;
        let mut outages = 0usize;
        for _ in 0..trials {
            let sum: u64 = loads
                .iter()
                .zip(&probs)
                .map(|(&l, &p)| if rng.next_f64() < p { l } else { 0 })
                .sum();
            outages += (sum < target) as usize;
        }
        let emp = outages as f64 / trials as f64;
        assert!((emp - exact).abs() < 0.005, "{emp} vs {exact}");
    }

    fn fleet() -> Vec<NodeSpec> {
        let mut nodes: Vec<NodeSpec> = (0..6)
            .map(|j| NodeSpec {
                params: NodeParams {
                    mu: 5.0 * 0.9f64.powi(j),
                    alpha: 2.0,
                    tau: 0.3,
                    p: 0.1,
                },
                max_load: 50.0,
            })
            .collect();
        nodes.push(NodeSpec {
            params: NodeParams { mu: 200.0, alpha: 50.0, tau: 0.02, p: 0.0 },
            max_load: 150.0,
        });
        nodes
    }

    #[test]
    fn solve_outage_meets_constraint() {
        let nodes = fleet();
        let m = 300.0;
        let sol = solve_outage(&nodes, m, 0.1, 0.05).expect("feasible");
        assert!(sol.outage <= 0.05 + 1e-6, "outage {}", sol.outage);
        assert!(sol.t_star > 0.0);
        for (l, n) in sol.loads.iter().zip(&nodes) {
            assert!(*l >= 0.0 && *l <= n.max_load + 1e-9);
        }
    }

    #[test]
    fn stricter_eta_needs_larger_deadline() {
        let nodes = fleet();
        let m = 300.0;
        let loose = solve_outage(&nodes, m, 0.1, 0.2).unwrap();
        let tight = solve_outage(&nodes, m, 0.1, 0.01).unwrap();
        assert!(
            tight.t_star >= loose.t_star,
            "tight {} !>= loose {}",
            tight.t_star,
            loose.t_star
        );
    }

    #[test]
    fn outage_target_above_capacity_is_infeasible() {
        let nodes = fleet(); // total capacity 6*50 + 150 = 450
        assert!(solve_outage(&nodes, 10_000.0, 0.0, 0.01).is_none());
    }
}
