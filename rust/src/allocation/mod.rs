//! CodedFedL load allocation and coding-redundancy optimization
//! (paper §III-C and §IV).
//!
//! Two-step structure exactly as the paper's Claim:
//!
//! * **Step 1** (eq. 24–26): for a fixed deadline `t`, maximise each node's
//!   expected return `E[R_j(t; ℓ̃_j)] = ℓ̃_j · P(T_j ≤ t)` independently.
//!   The Theorem shows the objective is piece-wise concave in `ℓ̃_j` with
//!   breakpoints `ℓ = μ(t − ντ)`; we maximise each concave piece with
//!   golden-section search (the paper used MATLAB `fminbnd`) and take the
//!   best. For reliable links (`p = 0`, the AWGN case) the closed form
//!   (eq. 34–35) via the Lambert `W₋₁` branch is used instead.
//! * **Step 2** (eq. 27): the maximised total expected aggregate return is
//!   monotonically increasing in `t` (App. C), so the minimum deadline with
//!   `E[R(t)] = m` is found by bisection.
//!
//! Nodes are indexed `j ∈ [n+1]` with the MEC server's computing unit last,
//! exactly as §IV's notation.

pub mod outage;

use crate::delay::NodeParams;
use crate::numerics::{bisect_min_t, golden_section_max, lambert_w_m1};

/// One node's optimisation input: its delay parameters and the cap on how
/// many points it can process per round (`ℓ_j` for clients, `u_max` for the
/// MEC server).
#[derive(Clone, Copy, Debug)]
pub struct NodeSpec {
    pub params: NodeParams,
    pub max_load: f64,
}

/// Result of the two-step optimisation (paper eq. 23).
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Optimal deadline time `t*` (seconds of simulated MEC time).
    pub t_star: f64,
    /// Optimal per-node loads `ℓ*_j(t*)`; last entry is `u*(t*)`.
    pub loads: Vec<f64>,
    /// Per-node expected returns at the optimum.
    pub expected_returns: Vec<f64>,
    /// Per-node probability of no return `1 − P(T_j ≤ t*)` at the optimal
    /// load — the weight-matrix input of §III-D.
    pub pnr: Vec<f64>,
}

impl Allocation {
    /// Coding redundancy `u*` (the server is the last node, §IV notation).
    pub fn u_star(&self) -> f64 {
        *self.loads.last().expect("allocation has at least the server node")
    }

    /// Total expected aggregate return `E[R(t*)]` (should equal `m`).
    pub fn total_expected_return(&self) -> f64 {
        self.expected_returns.iter().sum()
    }
}

/// Expected return `E[R_j(t; ℓ̃)] = ℓ̃ · P(T_j ≤ t)` (Theorem).
pub fn expected_return(node: &NodeParams, t: f64, ell: f64) -> f64 {
    if ell <= 0.0 {
        return 0.0;
    }
    ell * node.cdf(t, ell)
}

/// AWGN / reliable-link closed form for the optimal load, eq. (34).
///
/// Also covers `τ = 0` (free communication): the formulas hold with the
/// `2τ` offset collapsing to zero.
pub fn optimal_load_awgn(node: &NodeParams, t: f64, max_load: f64) -> (f64, f64) {
    let two_tau = 2.0 * node.tau;
    if t <= two_tau || max_load <= 0.0 {
        return (0.0, 0.0);
    }
    let s = slope_s(node);
    let zeta = max_load / s + two_tau;
    let ell = if t <= zeta { s * (t - two_tau) } else { max_load };
    let ell = ell.min(max_load);
    (ell, expected_return(node, t, ell))
}

/// The AWGN load slope `s_j = −α μ / (W₋₁(−e^{−(1+α)}) + 1)` (eq. 34).
pub fn slope_s(node: &NodeParams) -> f64 {
    let w = lambert_w_m1(-(-(1.0 + node.alpha)).exp());
    -node.alpha * node.mu / (w + 1.0)
}

/// Step-1 subproblem (eq. 25/26): maximise `E[R(t; ℓ̃)]` over
/// `0 ≤ ℓ̃ ≤ max_load` for a fixed deadline `t`. Returns `(ℓ*, E[R]*)`.
pub fn optimal_load(node: &NodeParams, t: f64, max_load: f64) -> (f64, f64) {
    if max_load <= 0.0 || t <= 2.0 * node.tau {
        return (0.0, 0.0);
    }
    if node.p == 0.0 || node.tau == 0.0 {
        return optimal_load_awgn(node, t, max_load);
    }
    // τ > 0 here (τ = 0 took the AWGN branch above), so the budget is
    // either a concrete bound or infeasible — never `NuMax::Unbounded`.
    let Some(nu_m) = node.nu_max(t).bounded() else {
        return (0.0, 0.0);
    };
    // Concavity breakpoints ℓ = μ(t − ντ), ν = ν_m … 2 (ascending in ℓ).
    // Beyond μ(t − 2τ) every step term is off and E[R] = 0.
    //
    // Perf: the NB(2, 1−p) retransmission pmf `(ν−1)(1−p)²p^{ν−2}` decays
    // geometrically, so pieces past ν_cut (tail mass < 1e-12) contribute
    // nothing distinguishable to the objective; they are merged into one
    // interval instead of golden-sectioned individually. At LTE-scale
    // deadlines (ν_m in the hundreds) this cuts `solve` from seconds to
    // milliseconds (EXPERIMENTS.md §Perf iteration 3) while the
    // grid-domination property test pins correctness.
    let nu_cut = if node.p > 0.0 {
        (2 + (-12.0 / node.p.log10()).ceil() as u64).min(nu_m)
    } else {
        nu_m
    };
    let mut bounds: Vec<f64> = Vec::new();
    let tail_lo = node.mu * (t - node.tau * nu_cut as f64);
    if nu_cut < nu_m && tail_lo > 0.0 {
        // single merged interval for the negligible-mass tail pieces
        bounds.push(tail_lo.min(max_load));
    }
    for nu in (2..=nu_cut).rev() {
        let b = node.mu * (t - node.tau * nu as f64);
        if b > 0.0 {
            bounds.push(b.min(max_load));
        }
        if b >= max_load {
            break; // further (larger) bounds are all clamped to max_load
        }
    }
    bounds.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    bounds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    bounds.dedup_by(|a, b| (*a - *b).abs() < 1e-12);

    let f = |ell: f64| expected_return(node, t, ell);
    let mut best = (0.0, 0.0);
    let mut lo = 0.0;
    for &hi in &bounds {
        if hi > lo {
            let (x, fx) = golden_section_max(lo, hi, 1e-10, f);
            if fx > best.1 {
                best = (x, fx);
            }
            // piece boundaries themselves are candidates (function is
            // continuous, but golden section may sit strictly inside)
            let fb = f(hi);
            if fb > best.1 {
                best = (hi, fb);
            }
        }
        lo = hi;
    }
    // The cap itself.
    let fc = f(max_load);
    if fc > best.1 {
        best = (max_load, fc);
    }
    best
}

/// Maximised total expected aggregate return at deadline `t` (Step 1 over
/// all nodes, eq. 24).
pub fn max_total_return(nodes: &[NodeSpec], t: f64) -> f64 {
    nodes
        .iter()
        .map(|n| optimal_load(&n.params, t, n.max_load).1)
        .sum()
}

/// Errors from the two-step solver (`thiserror` is unavailable offline,
/// so `Display` and `Error` are hand-implemented).
#[derive(Debug)]
pub enum AllocError {
    Infeasible { m: f64, sup: f64 },
    BadParams(String),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::Infeasible { m, sup } => write!(
                f,
                "target return m={m} exceeds the system's supremum {sup} \
                 (need coding redundancy u_max > m - Σ ℓ_j)"
            ),
            AllocError::BadParams(msg) => write!(f, "invalid node parameters: {msg}"),
        }
    }
}

impl std::error::Error for AllocError {}

/// Two-step optimisation (paper eq. 23 via eq. 24–27): minimum deadline
/// `t*` with `E[R(t*)] = m`, plus the optimal loads/redundancy at `t*`.
pub fn solve(nodes: &[NodeSpec], m: f64) -> Result<Allocation, AllocError> {
    for n in nodes {
        n.params.validate().map_err(AllocError::BadParams)?;
        if n.max_load < 0.0 {
            return Err(AllocError::BadParams(format!(
                "negative max_load {}",
                n.max_load
            )));
        }
    }
    // Supremum of the total return as t → ∞ is Σ max_load; E[R] < sup for
    // any finite t, so require strict slack (provided by parity data).
    let sup: f64 = nodes.iter().map(|n| n.max_load).sum();
    if sup <= m {
        return Err(AllocError::Infeasible { m, sup });
    }

    // Bracket: start just above the fastest node's 2τ, double until
    // feasible. The doubling terminates because E[R(t)] → sup > m.
    let t_min = nodes
        .iter()
        .map(|n| 2.0 * n.params.tau)
        .fold(f64::INFINITY, f64::min)
        .max(1e-9);
    let mut t_hi = t_min * 2.0 + 1.0;
    for _ in 0..128 {
        if max_total_return(nodes, t_hi) >= m {
            break;
        }
        t_hi *= 2.0;
    }
    let total = |t: f64| max_total_return(nodes, t);
    let t_star = bisect_min_t(t_min, t_hi, m, 1e-9, total)
        .ok_or(AllocError::Infeasible { m, sup })?;

    let mut loads = Vec::with_capacity(nodes.len());
    let mut ers = Vec::with_capacity(nodes.len());
    let mut pnr = Vec::with_capacity(nodes.len());
    for n in nodes {
        let (ell, er) = optimal_load(&n.params, t_star, n.max_load);
        let p_le = if ell > 0.0 { n.params.cdf(t_star, ell) } else { 0.0 };
        loads.push(ell);
        ers.push(er);
        pnr.push(1.0 - p_le);
    }
    Ok(Allocation { t_star, loads, expected_returns: ers, pnr })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 3's illustration parameters.
    fn fig3_node() -> NodeParams {
        NodeParams { mu: 2.0, alpha: 20.0, tau: 3f64.sqrt(), p: 0.9 }
    }

    #[test]
    fn expected_return_zero_cases() {
        let n = fig3_node();
        assert_eq!(expected_return(&n, 10.0, 0.0), 0.0);
        assert_eq!(expected_return(&n, 0.0, 5.0), 0.0);
    }

    #[test]
    fn optimal_load_beats_grid_scan() {
        // The optimizer must dominate a dense grid scan of the objective.
        let n = fig3_node();
        let t = 10.0;
        let cap = 8.0;
        let (_, er) = optimal_load(&n, t, cap);
        let grid_best = (1..=4000)
            .map(|i| expected_return(&n, t, cap * i as f64 / 4000.0))
            .fold(0.0f64, f64::max);
        assert!(
            er >= grid_best - 1e-6,
            "optimizer {er} < grid {grid_best}"
        );
    }

    #[test]
    fn optimal_load_awgn_matches_numeric() {
        let n = NodeParams { mu: 2.0, alpha: 2.0, tau: 1.0, p: 0.0 };
        for &t in &[2.5, 4.0, 9.0, 30.0] {
            let (ell_cf, er_cf) = optimal_load_awgn(&n, t, 12.0);
            let grid_best = (0..=6000)
                .map(|i| expected_return(&n, t, 12.0 * i as f64 / 6000.0))
                .fold(0.0f64, f64::max);
            assert!(
                (er_cf - grid_best).abs() < 1e-3 * (1.0 + grid_best),
                "t={t}: closed form {er_cf} (ell {ell_cf}) vs grid {grid_best}"
            );
        }
    }

    #[test]
    fn awgn_closed_form_piecewise_structure() {
        // eq. (34): 0 below 2τ, linear in t, then saturates at ℓ_max.
        let n = NodeParams { mu: 2.0, alpha: 2.0, tau: 1.0, p: 0.0 };
        let cap = 10.0;
        assert_eq!(optimal_load_awgn(&n, 1.9, cap).0, 0.0);
        let s = slope_s(&n);
        let (l1, _) = optimal_load_awgn(&n, 3.0, cap);
        assert!((l1 - s * 1.0).abs() < 1e-9);
        let zeta = cap / s + 2.0;
        let (l2, _) = optimal_load_awgn(&n, zeta + 50.0, cap);
        assert_eq!(l2, cap);
    }

    #[test]
    fn optimized_return_monotone_in_t() {
        // App. C: E[R_j(t; ℓ*(t))] is monotonically increasing in t.
        let n = fig3_node();
        let mut prev = -1.0;
        for i in 1..60 {
            let t = i as f64 * 0.5;
            let (_, er) = optimal_load(&n, t, 50.0);
            assert!(er >= prev - 1e-9, "t={t}: {er} < {prev}");
            prev = er;
        }
    }

    #[test]
    fn solve_reaches_target_return() {
        let clients: Vec<NodeSpec> = (0..8)
            .map(|j| NodeSpec {
                params: NodeParams {
                    mu: 2.0 * 0.9f64.powi(j),
                    alpha: 2.0,
                    tau: 0.5 * 1.05f64.powi(j),
                    p: 0.1,
                },
                max_load: 100.0,
            })
            .collect();
        let mut nodes = clients;
        nodes.push(NodeSpec {
            params: NodeParams { mu: 50.0, alpha: 20.0, tau: 0.05, p: 0.0 },
            max_load: 400.0,
        });
        let m = 800.0;
        let alloc = solve(&nodes, m).unwrap();
        assert!((alloc.total_expected_return() - m).abs() < 1e-3 * m);
        // minimality: slightly smaller t misses the target
        let smaller = max_total_return(&nodes, alloc.t_star * 0.99);
        assert!(smaller < m);
        for (l, n) in alloc.loads.iter().zip(nodes.iter()) {
            assert!(*l >= 0.0 && *l <= n.max_load + 1e-9);
        }
        for p in &alloc.pnr {
            assert!((0.0..=1.0).contains(p));
        }
    }

    #[test]
    fn solve_infeasible_without_redundancy() {
        // Σ ℓ_j = m exactly: E[R] < m for all finite t => infeasible.
        let nodes: Vec<NodeSpec> = (0..4)
            .map(|_| NodeSpec {
                params: NodeParams { mu: 2.0, alpha: 2.0, tau: 0.5, p: 0.1 },
                max_load: 25.0,
            })
            .collect();
        match solve(&nodes, 100.0) {
            Err(AllocError::Infeasible { .. }) => {}
            other => panic!("expected infeasible, got {other:?}"),
        }
    }

    #[test]
    fn solve_rejects_bad_params() {
        let nodes = [NodeSpec {
            params: NodeParams { mu: -1.0, alpha: 2.0, tau: 0.5, p: 0.1 },
            max_load: 10.0,
        }];
        assert!(matches!(solve(&nodes, 5.0), Err(AllocError::BadParams(_))));
    }

    #[test]
    fn more_redundancy_means_smaller_deadline() {
        // The paper's headline mechanism: larger u_max ⇒ smaller t*.
        let client = NodeSpec {
            params: NodeParams { mu: 2.0, alpha: 2.0, tau: 0.5, p: 0.2 },
            max_load: 50.0,
        };
        let server = |u: f64| NodeSpec {
            params: NodeParams { mu: 100.0, alpha: 20.0, tau: 0.02, p: 0.0 },
            max_load: u,
        };
        let m = 200.0;
        let mk = |u: f64| {
            let mut nodes = vec![client; 4];
            nodes.push(server(u));
            solve(&nodes, m).unwrap().t_star
        };
        let t_small = mk(20.0);
        let t_big = mk(80.0);
        assert!(
            t_big < t_small,
            "u=80 gives t*={t_big}, u=20 gives t*={t_small}"
        );
    }
}
