//! Scalar numerics used by the load-allocation optimizer:
//! the Lambert `W₋₁` branch (paper eq. 34), golden-section maximisation of
//! the piece-wise concave expected return, and bisection for the minimum
//! deadline time (paper Remark 5).

/// Machine-ish tolerance used by the iterative solvers.
pub const TOL: f64 = 1e-12;

/// Unit step `U(x) = 1` for `x > 0`, else `0` (paper's Theorem).
#[inline]
pub fn unit_step(x: f64) -> f64 {
    if x > 0.0 {
        1.0
    } else {
        0.0
    }
}

/// Lambert `W₋₁(x)` — the minor real branch, defined for `x ∈ [-1/e, 0)`,
/// returning `w ≤ -1` with `w e^w = x`.
///
/// Seeded with the asymptotic `ln(-x) - ln(-ln(-x))` (exact as `x → 0⁻`)
/// or a branch-point series near `-1/e`, then polished with Halley
/// iterations to ~1e-14 relative accuracy.
pub fn lambert_w_m1(x: f64) -> f64 {
    assert!(
        x >= -std::f64::consts::E.recip() - 1e-15 && x < 0.0,
        "W_-1 domain is [-1/e, 0), got {x}"
    );
    let e_inv = std::f64::consts::E.recip();
    if (x + e_inv).abs() < 1e-14 {
        return -1.0;
    }
    // Initial guess.
    let mut w = if x > -0.25 * e_inv {
        // Asymptotic near 0^-: W_-1(x) ~ ln(-x) - ln(-ln(-x)).
        let l1 = (-x).ln();
        let l2 = (-l1).ln();
        l1 - l2
    } else {
        // Branch-point series: p = -sqrt(2(1 + e x)), W ≈ -1 + p - p²/3.
        let p = -(2.0 * (1.0 + std::f64::consts::E * x)).sqrt();
        -1.0 + p - p * p / 3.0
    };
    // Halley iteration on f(w) = w e^w - x.
    for _ in 0..64 {
        let ew = w.exp();
        let f = w * ew - x;
        let denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0);
        let dw = f / denom;
        w -= dw;
        if dw.abs() <= 1e-14 * (1.0 + w.abs()) {
            break;
        }
    }
    w
}

/// Golden-section search for the maximum of a *unimodal* `f` on `[a, b]`.
///
/// Returns `(x*, f(x*))`. Used per concavity interval of the expected
/// return (paper Remark 4 — this is our stand-in for MATLAB's `fminbnd`).
pub fn golden_section_max(
    mut a: f64,
    mut b: f64,
    rel_tol: f64,
    f: impl Fn(f64) -> f64,
) -> (f64, f64) {
    assert!(b >= a, "invalid interval [{a}, {b}]");
    const INVPHI: f64 = 0.618_033_988_749_894_8; // 1/φ
    const INVPHI2: f64 = 0.381_966_011_250_105_2; // 1/φ²
    let mut h = b - a;
    if h <= rel_tol * (1.0 + a.abs()) {
        let x = 0.5 * (a + b);
        return (x, f(x));
    }
    let mut c = a + INVPHI2 * h;
    let mut d = a + INVPHI * h;
    let mut fc = f(c);
    let mut fd = f(d);
    // ~log(h/tol)/log(φ) iterations; cap generously.
    for _ in 0..200 {
        if h <= rel_tol * (1.0 + a.abs().max(b.abs())) {
            break;
        }
        if fc >= fd {
            b = d;
            d = c;
            fd = fc;
            h = b - a;
            c = a + INVPHI2 * h;
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            h = b - a;
            d = a + INVPHI * h;
            fd = f(d);
        }
    }
    if fc >= fd {
        (c, fc)
    } else {
        (d, fd)
    }
}

/// Bisection: smallest `t ∈ [lo, hi]` with `g(t) ≥ target`, for a
/// monotonically non-decreasing `g` (paper Remark 5). Returns `None` if
/// even `g(hi) < target`.
pub fn bisect_min_t(
    lo: f64,
    hi: f64,
    target: f64,
    abs_tol: f64,
    g: impl Fn(f64) -> f64,
) -> Option<f64> {
    if g(hi) < target {
        return None;
    }
    let (mut lo, mut hi) = (lo, hi);
    for _ in 0..200 {
        if hi - lo <= abs_tol * (1.0 + hi.abs()) {
            break;
        }
        let mid = 0.5 * (lo + hi);
        if g(mid) >= target {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_step_semantics() {
        assert_eq!(unit_step(1e-18), 1.0);
        assert_eq!(unit_step(0.0), 0.0);
        assert_eq!(unit_step(-1.0), 0.0);
    }

    #[test]
    fn lambert_w_m1_inverts() {
        // w e^w = x must hold across the domain.
        for &x in &[-1e-8, -1e-4, -0.05, -0.2, -0.3, -0.35, -0.367] {
            let w = lambert_w_m1(x);
            assert!(w <= -1.0, "W_-1({x}) = {w} must be <= -1");
            let back = w * w.exp();
            assert!(
                (back - x).abs() <= 1e-10 * x.abs().max(1e-12),
                "x={x} w={w} back={back}"
            );
        }
    }

    #[test]
    fn lambert_w_m1_branch_point() {
        let e_inv = std::f64::consts::E.recip();
        assert!((lambert_w_m1(-e_inv) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn lambert_w_m1_known_value() {
        // W_-1(-e^-2) solves w e^w = -e^-2; known w ≈ -3.146193220620583.
        let w = lambert_w_m1(-(-2.0f64).exp());
        assert!((w + 3.146_193_220_620_583).abs() < 1e-9, "{w}");
    }

    #[test]
    #[should_panic(expected = "W_-1 domain")]
    fn lambert_w_m1_domain_checked() {
        lambert_w_m1(0.1);
    }

    #[test]
    fn golden_section_finds_parabola_peak() {
        let (x, fx) = golden_section_max(0.0, 10.0, 1e-10, |x| -(x - 3.7) * (x - 3.7) + 2.0);
        assert!((x - 3.7).abs() < 1e-6, "{x}");
        assert!((fx - 2.0).abs() < 1e-10);
    }

    #[test]
    fn golden_section_degenerate_interval() {
        let (x, fx) = golden_section_max(2.0, 2.0, 1e-10, |x| x);
        assert_eq!(x, 2.0);
        assert_eq!(fx, 2.0);
    }

    #[test]
    fn golden_section_boundary_max() {
        // Monotone increasing on interval => max at right edge.
        let (x, _) = golden_section_max(0.0, 1.0, 1e-10, |x| x);
        assert!(x > 1.0 - 1e-6, "{x}");
    }

    #[test]
    fn bisect_finds_threshold() {
        let t = bisect_min_t(0.0, 100.0, 0.5, 1e-10, |t| 1.0 - (-t).exp()).unwrap();
        assert!((t - std::f64::consts::LN_2).abs() < 1e-6, "{t}");
    }

    #[test]
    fn bisect_infeasible_is_none() {
        assert!(bisect_min_t(0.0, 10.0, 2.0, 1e-9, |t| 1.0 - (-t).exp()).is_none());
    }
}
