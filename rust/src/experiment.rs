//! The experiment session API: `ExperimentBuilder` → [`Session`] →
//! [`Scheme`](crate::schemes::Scheme) runs.
//!
//! A [`Session`] owns the one-time shared state of an experiment — the
//! [`FedSetup`] (fleet, non-IID shards, RFF-embedded data, test set) and
//! the compiled [`Runtime`] — so any number of schemes can run on
//! *identical* data and delay statistics, which is what makes the paper's
//! comparisons fair. The builder layers config presets, file overrides and
//! typed field overrides, and every validation error names the offending
//! field.
//!
//! ```no_run
//! use codedfedl::{ExperimentBuilder, schemes::{CodedFedL, NaiveUncoded}};
//!
//! let session = ExperimentBuilder::preset("tiny")?.epochs(8).build()?;
//! let naive = session.run(&mut NaiveUncoded::new())?;
//! let coded = session.run(&mut CodedFedL::new(0.3))?;
//! assert!(coded.history.total_sim_time() < naive.history.total_sim_time());
//! # anyhow::Ok(())
//! ```

use std::path::Path;

use anyhow::{Context, Result};

use crate::coding::{CodeSpec, GeneratorKind, RecoveryMode};
use crate::comm::{CodecSpec, PayloadSpec};
use crate::conf::{ConfError, ExperimentConfig};
use crate::coordinator::checkpoint::ResumeSpec;
use crate::coordinator::{engine, FedSetup, RoundObserver, TrainOutcome};
use crate::runtime::{Runtime, RuntimeShapes};
use crate::schemes::{CodedFedL, Scheme, SchemeSpec};
use crate::sim::fault::{DeadlineSpec, FaultSpec};
use crate::sim::scenario::ScenarioSpec;
use crate::tensor::SimdPolicy;
use crate::topology::{AggregationMode, AsymLinkSpec, ParticipationSpec};

/// Derive the runtime shape set from an experiment config (must agree with
/// `python/compile/shapes.py`; the PJRT manifest check fails fast
/// otherwise).
pub fn shapes_for(cfg: &ExperimentConfig) -> RuntimeShapes {
    RuntimeShapes {
        d: cfg.dim,
        q: cfg.q,
        c: cfg.classes,
        l_client: cfg.local_batch,
        u_max: cfg.u_max,
        b_embed: cfg.local_batch,
    }
}

/// Load the runtime for a config (native worker-thread count comes from
/// `cfg.threads`, `0` = available parallelism; the GEMM microkernel ISA
/// is resolved once here from `cfg.simd`).
pub fn load_runtime(cfg: &ExperimentConfig) -> Result<Runtime> {
    let dir = Path::new(&cfg.artifacts_dir);
    Runtime::load_with_policy(dir, shapes_for(cfg), cfg.threads, cfg.simd)
}

macro_rules! setters {
    ($($(#[$doc:meta])* $name:ident: $ty:ty),* $(,)?) => {
        $(
            $(#[$doc])*
            pub fn $name(mut self, v: $ty) -> Self {
                self.cfg.$name = v;
                self
            }
        )*
    };
}

/// Builds a [`Session`]: preset or file config, field overrides, then
/// `build()` validates, loads the runtime and materialises the
/// [`FedSetup`].
#[derive(Clone, Debug)]
pub struct ExperimentBuilder {
    cfg: ExperimentConfig,
}

impl Default for ExperimentBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ExperimentBuilder {
    /// Start from the repo's reduced `default` scale.
    pub fn new() -> Self {
        ExperimentBuilder { cfg: ExperimentConfig::default() }
    }

    /// Start from a named preset: `tiny` | `default` | `paper`. Unknown
    /// names are an error listing the valid ones.
    pub fn preset(name: &str) -> Result<Self> {
        Ok(ExperimentBuilder { cfg: ExperimentConfig::preset(name).map_err(into_anyhow)? })
    }

    /// Start from a TOML config file. Unknown or mistyped keys fail with
    /// the section and field name (`[training] lr: expected float, …`).
    pub fn from_file(path: &Path) -> Result<Self> {
        Ok(ExperimentBuilder {
            cfg: ExperimentConfig::from_file(path).map_err(into_anyhow)?,
        })
    }

    /// Start from an explicit config value.
    pub fn from_config(cfg: ExperimentConfig) -> Self {
        ExperimentBuilder { cfg }
    }

    setters! {
        /// Root RNG seed; every stochastic object derives from it.
        seed: u64,
        /// Number of clients n.
        clients: usize,
        /// Raw feature dimension d.
        dim: usize,
        /// RFF dimension q.
        q: usize,
        /// Number of classes c.
        classes: usize,
        /// RBF kernel width σ.
        sigma: f64,
        /// Per-client mini-batch rows ℓ_j.
        local_batch: usize,
        /// Global mini-batches per epoch.
        steps_per_epoch: usize,
        /// Total training epochs.
        epochs: usize,
        /// Initial learning rate.
        lr: f64,
        /// Step-decay factor.
        lr_decay: f64,
        /// Epochs at which the decay applies.
        lr_decay_epochs: Vec<usize>,
        /// L2 regularisation λ.
        l2: f64,
        /// Evaluate every `eval_every` rounds (≥ 1; final round always).
        eval_every: usize,
        /// Coordinator deadline (`DeadlineSpec::None` — the default —
        /// keeps the open-ended coordinator bit-identical; `Quantile` /
        /// `Fixed` close each round and resolve stragglers through the
        /// engine's degradation ladder).
        deadline: DeadlineSpec,
        /// Native worker threads (0 = available parallelism).
        threads: usize,
        /// SIMD microkernel policy (`Auto` detects AVX2+FMA / NEON once;
        /// `Scalar` pins the bit-exact fallback).
        simd: SimdPolicy,
        /// Per-round network scenario (`ScenarioSpec::Static` — the
        /// default — is bit-identical to the fixed-fleet behaviour;
        /// `Dropout`/`Fading`/`Burst` open the non-stationary regimes).
        scenario: ScenarioSpec,
        /// Fault injection (`FaultSpec::None` — the default — is
        /// bit-identical to the fault-free engine; `Crash`/`Link`/
        /// `Parity`/`Mixed` inject seeded failures that compose with
        /// every scenario).
        faults: FaultSpec,
        /// Asymmetric downlink/uplink link overrides (`None` keeps the
        /// paper's reciprocal §V-A links).
        fleet_asym: Option<AsymLinkSpec>,
        /// Simulated fleet size N (`None` keeps the fleet at `clients`;
        /// `Some(N ≥ clients)` runs the ladder-tiled mega-fleet whose
        /// data shards tile the training shards).
        fleet_n: Option<usize>,
        /// Per-round participation (`ParticipationSpec::Full` — the
        /// default, bit-identical to the pre-participation engine — or
        /// `Sample { k }` for seeded scheme-independent k-of-N rosters).
        participation: ParticipationSpec,
        /// Clients per lazily-built fleet shard arena (storage
        /// granularity only; the fleet is identical for every value).
        shard_size: usize,
        /// Gradient fold mode (`AggregationMode::Flat` — the historical
        /// sequential fold — or `Hier` for worker-pool per-shard partial
        /// sums in a documented thread-invariant order).
        aggregation: AggregationMode,
        /// Max parity rows (AOT-compiled shape).
        u_max: usize,
        /// Generator matrix distribution.
        generator: GeneratorKind,
        /// Erasure code over client gradient shards (`CodeSpec::Dense` —
        /// the paper's generator — or `CodeSpec::Rateless`).
        code: CodeSpec,
        /// Straggler recovery mode for the coded scheme
        /// (`RecoveryMode::Expectation` — the paper's — or
        /// `RecoveryMode::Exact` for bit-exact erasure decoding).
        recovery: RecoveryMode,
        /// Gradient uplink codec (`CodecSpec::None` — the default — keeps
        /// payloads and histories bit-identical; `Q8`/`Bitpack` quantize
        /// each uploaded gradient, shrink the modelled uplink bytes and
        /// reprice every uplink leg, shifting the coded scheme's optimal
        /// (load, redundancy) split).
        codec: CodecSpec,
        /// Payload pricing mode (`PayloadSpec::Auto` — the default —
        /// derives per-leg byte scales from the codec; `Fixed` pins the
        /// pre-codec fixed-size payloads as an ablation control).
        payload: PayloadSpec,
        /// Write a crash-consistent checkpoint every this many rounds
        /// (0 — the default — disables periodic checkpointing; any
        /// positive value also snapshots at graceful shutdown). Never
        /// changes the realized history.
        checkpoint_every: usize,
        /// Checkpoint file path (`None` derives
        /// `checkpoint_<scheme-tag>.ckpt` under the artifacts dir).
        checkpoint_path: Option<String>,
        /// How the run starts relative to an existing checkpoint
        /// (`ResumeSpec::Off` — the default — starts fresh; `Auto`
        /// resumes if the file exists; `Path` resumes from exactly that
        /// file). A resumed run is bit-identical to the uninterrupted
        /// one.
        resume: ResumeSpec,
        /// Train set size.
        train_size: usize,
        /// Test set size.
        test_size: usize,
        /// Artifacts directory for the PJRT runtime.
        artifacts_dir: String,
        /// Dataset family ("mnist" | "fashion" | "easy").
        dataset: String,
    }

    /// The config as currently layered (pre-validation).
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Validate the layered config, load/compile the runtime and build the
    /// shared [`FedSetup`]. Every config error names the offending field.
    pub fn build(self) -> Result<Session> {
        self.cfg.validate().map_err(into_anyhow)?;
        let rt = load_runtime(&self.cfg)?;
        let setup = FedSetup::build(&self.cfg, &rt)?;
        Ok(Session { setup, rt })
    }
}

fn into_anyhow(e: ConfError) -> anyhow::Error {
    anyhow::anyhow!(e.to_string())
}

/// One experiment's live state: the shared [`FedSetup`] plus the compiled
/// [`Runtime`]. Run as many schemes as you like — they all see identical
/// data, fleet and delay statistics.
pub struct Session {
    setup: FedSetup,
    rt: Runtime,
}

impl Session {
    /// Assemble a session from parts built elsewhere (advanced: custom
    /// setups, shared runtimes in benches).
    pub fn from_parts(setup: FedSetup, rt: Runtime) -> Self {
        Session { setup, rt }
    }

    pub fn setup(&self) -> &FedSetup {
        &self.setup
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.setup.cfg
    }

    /// Run a scheme to completion on this session's data and fleet.
    pub fn run(&self, scheme: &mut dyn Scheme) -> Result<TrainOutcome> {
        engine::run(&self.setup, &self.rt, scheme, &mut [])
    }

    /// [`Session::run`] with one [`RoundObserver`] receiving a
    /// [`RoundEvent`](crate::coordinator::RoundEvent) per round.
    pub fn run_observed(
        &self,
        scheme: &mut dyn Scheme,
        observer: &mut dyn RoundObserver,
    ) -> Result<TrainOutcome> {
        engine::run(&self.setup, &self.rt, scheme, &mut [observer])
    }

    /// [`Session::run`] with any number of observers.
    pub fn run_with(
        &self,
        scheme: &mut dyn Scheme,
        observers: &mut [&mut dyn RoundObserver],
    ) -> Result<TrainOutcome> {
        engine::run(&self.setup, &self.rt, scheme, observers)
    }

    /// Convenience: build and run a [`SchemeSpec`] (the CLI/TOML string
    /// form — `SchemeSpec::parse("coded:delta=0.1")`). The coded scheme
    /// picks up the session's `[coding] code` / `recovery` configuration;
    /// the defaults (dense, expectation) reproduce the paper's scheme
    /// bit-for-bit.
    pub fn run_spec(&self, spec: SchemeSpec) -> Result<TrainOutcome> {
        let cfg = self.config();
        let mut scheme: Box<dyn Scheme> = match spec {
            SchemeSpec::Coded { delta } => Box::new(
                CodedFedL::new(delta).with_code(cfg.code).with_recovery(cfg.recovery),
            ),
            other => other.build(),
        };
        self.run(scheme.as_mut())
            .with_context(|| format!("running scheme {}", spec.label()))
    }
}
