//! Datasets and the paper's non-IID client partitioning (§V-A).
//!
//! Real MNIST / Fashion-MNIST is loaded from IDX files when present
//! (`data/mnist/`, `data/fashion/`); otherwise the seeded synthetic
//! generators in [`synth`] stand in (DESIGN.md §Substitutions — the
//! evaluated phenomena are delay-model and sharding properties, preserved
//! by any 10-class dataset).

pub mod idx;
pub mod shard;
pub mod synth;

use crate::tensor::Mat;

/// A supervised dataset: features `x [m, d]`, one-hot labels `y [m, c]`,
/// and the integer class labels kept for sorting/eval.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Mat,
    pub y: Mat,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.x.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn feature_dim(&self) -> usize {
        self.x.cols()
    }

    pub fn num_classes(&self) -> usize {
        self.y.cols()
    }

    /// Build one-hot labels from integer labels.
    pub fn from_labels(x: Mat, labels: Vec<u8>, num_classes: usize) -> Self {
        assert_eq!(x.rows(), labels.len());
        let mut y = Mat::zeros(labels.len(), num_classes);
        for (i, &l) in labels.iter().enumerate() {
            assert!((l as usize) < num_classes, "label {l} >= c {num_classes}");
            y.set(i, l as usize, 1.0);
        }
        Dataset { x, y, labels }
    }

    /// Row subset (gather) keeping all three views aligned.
    pub fn gather(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.gather_rows(idx),
            y: self.y.gather_rows(idx),
            labels: idx.iter().map(|&i| self.labels[i]).collect(),
        }
    }

    /// Contiguous row range.
    pub fn slice(&self, start: usize, n: usize) -> Dataset {
        let idx: Vec<usize> = (start..start + n).collect();
        self.gather(&idx)
    }

    /// Normalise features to `[0, 1]` in place (paper §V-A normalises
    /// before kernel embedding). No-op for an all-constant feature matrix.
    pub fn normalize_01(&mut self) {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in self.x.as_slice() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let span = hi - lo;
        if span <= 0.0 {
            return;
        }
        for v in self.x.as_mut_slice() {
            *v = (*v - lo) / span;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Mat::from_fn(4, 2, |r, c| (r * 2 + c) as f32);
        Dataset::from_labels(x, vec![0, 1, 2, 1], 3)
    }

    #[test]
    fn one_hot_is_correct() {
        let d = toy();
        assert_eq!(d.y.row(0), &[1.0, 0.0, 0.0]);
        assert_eq!(d.y.row(1), &[0.0, 1.0, 0.0]);
        assert_eq!(d.y.row(2), &[0.0, 0.0, 1.0]);
        assert_eq!(d.num_classes(), 3);
        assert_eq!(d.len(), 4);
        assert_eq!(d.feature_dim(), 2);
    }

    #[test]
    #[should_panic(expected = "label")]
    fn one_hot_validates_labels() {
        Dataset::from_labels(Mat::zeros(1, 1), vec![5], 3);
    }

    #[test]
    fn gather_keeps_alignment() {
        let d = toy();
        let g = d.gather(&[2, 0]);
        assert_eq!(g.labels, vec![2, 0]);
        assert_eq!(g.x.row(0), &[4.0, 5.0]);
        assert_eq!(g.y.row(0), &[0.0, 0.0, 1.0]);
    }

    #[test]
    fn slice_is_contiguous_gather() {
        let d = toy();
        let s = d.slice(1, 2);
        assert_eq!(s.labels, vec![1, 2]);
        assert_eq!(s.x.row(0), &[2.0, 3.0]);
    }

    #[test]
    fn normalize_01_bounds() {
        let mut d = toy();
        d.normalize_01();
        let s = d.x.as_slice();
        assert_eq!(s.iter().cloned().fold(f32::INFINITY, f32::min), 0.0);
        assert_eq!(s.iter().cloned().fold(f32::NEG_INFINITY, f32::max), 1.0);
    }

    #[test]
    fn normalize_01_constant_is_noop() {
        let mut d = Dataset::from_labels(
            Mat::from_vec(2, 1, vec![3.0, 3.0]),
            vec![0, 1],
            2,
        );
        d.normalize_01();
        assert_eq!(d.x.as_slice(), &[3.0, 3.0]);
    }
}
