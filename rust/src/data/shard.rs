//! The paper's non-IID data placement (§V-A).
//!
//! The training set is sorted by class label, partitioned into `n` equal
//! shards (so most shards contain 1–2 classes), clients are sorted by their
//! *expected* total round time (eq. 15 at the local mini-batch size), and
//! shards are assigned in that order. This is what makes greedy-uncoded
//! miss whole classes — the slowest clients own entire classes.

use super::Dataset;
use crate::delay::NodeParams;

/// Sort-by-label + equal shards + assignment in expected-delay order.
///
/// Returns per-client datasets, index `j` = client `j` (matching the order
/// of `clients`). `mini_batch` is the per-client mini-batch size used in
/// the expected-delay formula (the paper uses ℓ_j = 400).
pub fn non_iid_shards(
    ds: &Dataset,
    clients: &[NodeParams],
    mini_batch: f64,
) -> Vec<Dataset> {
    let n = clients.len();
    assert!(n > 0, "no clients");
    assert_eq!(ds.len() % n, 0, "dataset size {} not divisible by n {}", ds.len(), n);
    // Stable sort of data indices by label.
    let mut order: Vec<usize> = (0..ds.len()).collect();
    order.sort_by_key(|&i| ds.labels[i]);

    // Clients sorted by expected total delay (fastest first).
    let mut by_speed: Vec<usize> = (0..n).collect();
    by_speed.sort_by(|&a, &b| {
        clients[a]
            .mean_delay(mini_batch)
            .partial_cmp(&clients[b].mean_delay(mini_batch))
            .unwrap()
    });

    let shard = ds.len() / n;
    let mut out: Vec<Option<Dataset>> = (0..n).map(|_| None).collect();
    for (rank, &client) in by_speed.iter().enumerate() {
        let idx = &order[rank * shard..(rank + 1) * shard];
        out[client] = Some(ds.gather(idx));
    }
    out.into_iter().map(|d| d.unwrap()).collect()
}

/// IID control: shuffle indices with a seeded permutation and deal equal
/// shards (used by ablation benches).
pub fn iid_shards(ds: &Dataset, n: usize, rng: &mut crate::rng::Rng) -> Vec<Dataset> {
    assert!(n > 0 && ds.len() % n == 0);
    let perm = rng.permutation(ds.len());
    let shard = ds.len() / n;
    (0..n)
        .map(|j| ds.gather(&perm[j * shard..(j + 1) * shard]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, mnist_like};
    use crate::rng::Rng;

    fn fleet(n: usize) -> Vec<NodeParams> {
        (0..n)
            .map(|j| NodeParams {
                mu: 100.0 * 0.8f64.powi(j as i32),
                alpha: 2.0,
                tau: 0.01 * 1.05f64.powi(j as i32),
                p: 0.1,
            })
            .collect()
    }

    #[test]
    fn shards_equal_size_and_partition() {
        let ds = generate(&mnist_like(8), 200, &mut Rng::seed_from(1));
        let shards = non_iid_shards(&ds, &fleet(10), 20.0);
        assert_eq!(shards.len(), 10);
        assert!(shards.iter().all(|s| s.len() == 20));
    }

    #[test]
    fn shards_are_label_concentrated() {
        let ds = generate(&mnist_like(8), 500, &mut Rng::seed_from(2));
        let shards = non_iid_shards(&ds, &fleet(10), 50.0);
        for s in &shards {
            let distinct: std::collections::HashSet<u8> =
                s.labels.iter().copied().collect();
            assert!(distinct.len() <= 2, "shard has {} classes", distinct.len());
        }
    }

    #[test]
    fn fastest_client_gets_lowest_labels() {
        let ds = generate(&mnist_like(8), 100, &mut Rng::seed_from(3));
        let clients = fleet(10); // client 0 is fastest by construction
        let shards = non_iid_shards(&ds, &clients, 10.0);
        let min0 = *shards[0].labels.iter().min().unwrap();
        let max0 = *shards[0].labels.iter().max().unwrap();
        assert_eq!(min0, 0);
        assert!(max0 <= 1);
        // slowest client owns the top classes
        let min_last = *shards[9].labels.iter().min().unwrap();
        assert!(min_last >= 8);
    }

    #[test]
    fn iid_shards_cover_classes() {
        let ds = generate(&mnist_like(8), 400, &mut Rng::seed_from(4));
        let shards = iid_shards(&ds, 4, &mut Rng::seed_from(5));
        for s in &shards {
            let distinct: std::collections::HashSet<u8> =
                s.labels.iter().copied().collect();
            assert!(distinct.len() >= 8, "iid shard lost classes");
        }
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn rejects_ragged_partition() {
        let ds = generate(&mnist_like(8), 101, &mut Rng::seed_from(6));
        non_iid_shards(&ds, &fleet(10), 10.0);
    }
}
