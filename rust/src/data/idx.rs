//! Loader for the IDX format used by MNIST / Fashion-MNIST distribution
//! files (`train-images-idx3-ubyte` etc.), so the real datasets are used
//! automatically when present (see `data::load_or_synth`).

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;
use crate::tensor::Mat;

/// Parse an IDX3 (images) byte buffer into `[n, rows*cols]` features
/// scaled to `[0, 1]`.
pub fn parse_images(buf: &[u8]) -> Result<Mat> {
    if buf.len() < 16 {
        bail!("idx3 file too short ({} bytes)", buf.len());
    }
    let magic = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if magic != 0x0000_0803 {
        bail!("bad idx3 magic {magic:#010x} (want 0x00000803)");
    }
    let n = u32::from_be_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    let r = u32::from_be_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
    let c = u32::from_be_bytes([buf[12], buf[13], buf[14], buf[15]]) as usize;
    let want = 16 + n * r * c;
    if buf.len() != want {
        bail!("idx3 size mismatch: header says {want} bytes, file has {}", buf.len());
    }
    let mut data = Vec::with_capacity(n * r * c);
    data.extend(buf[16..].iter().map(|&b| b as f32 / 255.0));
    Ok(Mat::from_vec(n, r * c, data))
}

/// Parse an IDX1 (labels) byte buffer.
pub fn parse_labels(buf: &[u8]) -> Result<Vec<u8>> {
    if buf.len() < 8 {
        bail!("idx1 file too short ({} bytes)", buf.len());
    }
    let magic = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if magic != 0x0000_0801 {
        bail!("bad idx1 magic {magic:#010x} (want 0x00000801)");
    }
    let n = u32::from_be_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    if buf.len() != 8 + n {
        bail!("idx1 size mismatch: header says {} bytes, file has {}", 8 + n, buf.len());
    }
    Ok(buf[8..].to_vec())
}

/// Load an MNIST-layout directory: `{images_file, labels_file}`.
pub fn load_pair(images: &Path, labels: &Path, num_classes: usize) -> Result<Dataset> {
    let ibuf = fs::read(images).with_context(|| format!("reading {images:?}"))?;
    let lbuf = fs::read(labels).with_context(|| format!("reading {labels:?}"))?;
    let x = parse_images(&ibuf)?;
    let l = parse_labels(&lbuf)?;
    if x.rows() != l.len() {
        bail!("images ({}) / labels ({}) count mismatch", x.rows(), l.len());
    }
    Ok(Dataset::from_labels(x, l, num_classes))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx3(n: usize, r: usize, c: usize, pixels: &[u8]) -> Vec<u8> {
        let mut b = vec![];
        b.extend(0x0803u32.to_be_bytes());
        b.extend((n as u32).to_be_bytes());
        b.extend((r as u32).to_be_bytes());
        b.extend((c as u32).to_be_bytes());
        b.extend(pixels);
        b
    }

    fn idx1(labels: &[u8]) -> Vec<u8> {
        let mut b = vec![];
        b.extend(0x0801u32.to_be_bytes());
        b.extend((labels.len() as u32).to_be_bytes());
        b.extend(labels);
        b
    }

    #[test]
    fn parses_images_and_scales() {
        let buf = idx3(2, 1, 2, &[0, 255, 128, 0]);
        let m = parse_images(&buf).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.get(0, 1), 1.0);
        assert!((m.get(1, 0) - 128.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn parses_labels() {
        assert_eq!(parse_labels(&idx1(&[3, 1, 4])).unwrap(), vec![3, 1, 4]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = idx3(1, 1, 1, &[0]);
        buf[3] = 0x99;
        assert!(parse_images(&buf).is_err());
        let mut lb = idx1(&[1]);
        lb[3] = 0x99;
        assert!(parse_labels(&lb).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut buf = idx3(2, 2, 2, &[0; 8]);
        buf.pop();
        assert!(parse_images(&buf).is_err());
        assert!(parse_images(&[1, 2, 3]).is_err());
        assert!(parse_labels(&[1, 2]).is_err());
    }
}
