//! Seeded synthetic stand-ins for MNIST and Fashion-MNIST
//! (DESIGN.md §Substitutions).
//!
//! Each class is a Gaussian cluster around a random prototype in `[0, 1]^d`
//! pushed through a mild non-linear warp, so that (a) a *linear* model on
//! raw features underfits while the RFF kernel model separates well —
//! preserving the paper's motivation for kernel embedding — and (b) the
//! label-sorted non-IID sharding starves greedy-uncoded of whole classes
//! exactly as in §V-B. "Fashion" uses closer prototypes + higher noise so
//! it is the harder dataset, mirroring MNIST vs Fashion-MNIST.

use super::Dataset;
use crate::rng::Rng;
use crate::tensor::Mat;

/// Generation knobs for one synthetic dataset family.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    pub dim: usize,
    pub classes: usize,
    /// Cluster spread around the class prototype.
    pub noise: f32,
    /// Prototype spread; smaller = classes closer = harder.
    pub proto_scale: f32,
    /// Strength of the non-linear warp mixed into the features.
    pub warp: f32,
}

/// MNIST-like: separable but with enough overlap that accuracy climbs
/// gradually into the low-to-mid 90s (like MNIST under kernel regression).
pub fn mnist_like(dim: usize) -> SynthSpec {
    SynthSpec { dim, classes: 10, noise: 0.7, proto_scale: 0.38, warp: 1.0 }
}

/// Easy family used by smoke tests and the quickstart: well-separated
/// clusters that any scheme learns within a handful of iterations.
pub fn easy(dim: usize) -> SynthSpec {
    SynthSpec { dim, classes: 10, noise: 0.18, proto_scale: 1.0, warp: 0.4 }
}

/// Fashion-MNIST-like: closer prototypes, noisier — systematically lower
/// accuracy at the same iteration count, like the real pair.
pub fn fashion_like(dim: usize) -> SynthSpec {
    SynthSpec { dim, classes: 10, noise: 0.9, proto_scale: 0.33, warp: 1.2 }
}

/// Generate `n` points of the family. Deterministic in `(spec, rng seed)`.
pub fn generate(spec: &SynthSpec, n: usize, rng: &mut Rng) -> Dataset {
    assert!(spec.classes > 0 && spec.dim > 0);
    // Class prototypes.
    let mut protos = Mat::zeros(spec.classes, spec.dim);
    {
        let s = protos.as_mut_slice();
        for v in s.iter_mut() {
            *v = rng.next_f32() * spec.proto_scale;
        }
    }
    let mut x = Mat::zeros(n, spec.dim);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        // Balanced classes, deterministic label sequence then shuffled use
        // happens at shard level; here round-robin keeps counts exact.
        let cls = i % spec.classes;
        labels.push(cls as u8);
        for d in 0..spec.dim {
            let base = protos.get(cls, d) + spec.noise * rng.next_normal() as f32;
            // Non-linear warp: mixes coordinates through sin so raw-feature
            // linear regression underfits but the RBF kernel separates.
            let neighbour = protos.get(cls, (d + 1) % spec.dim);
            let warped =
                base + spec.warp * (3.0 * base + 2.0 * neighbour).sin() * spec.noise;
            x.set(i, d, warped);
        }
    }
    let mut ds = Dataset::from_labels(x, labels, spec.classes);
    ds.normalize_01();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let spec = mnist_like(16);
        let a = generate(&spec, 100, &mut Rng::seed_from(9));
        let b = generate(&spec, 100, &mut Rng::seed_from(9));
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn balanced_classes() {
        let ds = generate(&mnist_like(8), 200, &mut Rng::seed_from(1));
        let mut counts = [0usize; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn features_normalized() {
        let ds = generate(&fashion_like(8), 500, &mut Rng::seed_from(2));
        for &v in ds.x.as_slice() {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn classes_are_clustered() {
        // Same-class points are closer on average than cross-class points.
        // Uses a low-noise spec: the mnist_like/fashion_like presets are
        // deliberately hard (heavy overlap) so their margin is small.
        let spec = SynthSpec { dim: 12, classes: 10, noise: 0.2, proto_scale: 1.0, warp: 0.4 };
        let ds = generate(&spec, 400, &mut Rng::seed_from(3));
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let (mut same, mut same_n, mut diff, mut diff_n) = (0.0f64, 0, 0.0f64, 0);
        for i in (0..400).step_by(7) {
            for j in (0..400).step_by(11) {
                if i == j {
                    continue;
                }
                let d = dist(ds.x.row(i), ds.x.row(j)) as f64;
                if ds.labels[i] == ds.labels[j] {
                    same += d;
                    same_n += 1;
                } else {
                    diff += d;
                    diff_n += 1;
                }
            }
        }
        assert!(same / same_n as f64 <= 0.8 * (diff / diff_n as f64));
    }

    #[test]
    fn fashion_is_harder_than_mnist() {
        // Harder = smaller between/within cluster separation ratio.
        let sep = |spec: &SynthSpec| -> f64 {
            let ds = generate(spec, 300, &mut Rng::seed_from(4));
            let d = ds.feature_dim();
            // class means
            let mut means = vec![vec![0.0f64; d]; spec.classes];
            let mut counts = vec![0usize; spec.classes];
            for i in 0..ds.len() {
                counts[ds.labels[i] as usize] += 1;
                for k in 0..d {
                    means[ds.labels[i] as usize][k] += ds.x.get(i, k) as f64;
                }
            }
            for (m, &c) in means.iter_mut().zip(&counts) {
                for v in m.iter_mut() {
                    *v /= c as f64;
                }
            }
            let mut within = 0.0;
            for i in 0..ds.len() {
                let m = &means[ds.labels[i] as usize];
                within += (0..d)
                    .map(|k| (ds.x.get(i, k) as f64 - m[k]).powi(2))
                    .sum::<f64>();
            }
            within /= ds.len() as f64;
            let mut between = 0.0;
            let mut n = 0;
            for a in 0..spec.classes {
                for b in (a + 1)..spec.classes {
                    between += (0..d)
                        .map(|k| (means[a][k] - means[b][k]).powi(2))
                        .sum::<f64>();
                    n += 1;
                }
            }
            between / n as f64 / within
        };
        assert!(sep(&fashion_like(10)) < sep(&mnist_like(10)));
    }
}
